//! Partition plan: the joint decision variable of §3.4 — where to cut the
//! model (`x_i`), the data-parallel degree (`d` / `y_k`), and the memory
//! tier of each stage's workers (`m_i` / `z_{i,j}`).

use std::fmt;

use crate::model::layer::ModelProfile;
use crate::platform::PlatformSpec;
use crate::util::json::{Json, JsonError};

/// A complete training configuration for one model on one platform.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    /// Sorted cut positions: `i` ∈ `cuts` means a boundary after layer `i`
    /// (0-based; valid range `0..L-1`). `cuts.len()+1` stages.
    pub cuts: Vec<usize>,
    /// Data-parallel degree `d` (uniform across stages, §3.4.1).
    pub dp: usize,
    /// Memory tier index per stage (length = number of stages).
    pub stage_tiers: Vec<usize>,
    /// Total number of micro-batches `M` = global batch / micro-batch size.
    pub n_micro_global: usize,
}

#[derive(Debug, PartialEq)]
pub enum PlanError {
    BadCuts { cuts: Vec<usize>, l: usize },
    TierLen { got: usize, want: usize },
    BadTier { tier: usize, n_tiers: usize },
    BadDp { dp: usize, m: usize },
    OutOfMemory { stage: usize, need_mb: u64, have_mb: u64 },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::BadCuts { cuts, l } => write!(
                f,
                "cuts must be strictly increasing and < L-1 (L={l}): {cuts:?}"
            ),
            PlanError::TierLen { got, want } => write!(
                f,
                "stage_tiers length {got} != number of stages {want}"
            ),
            PlanError::BadTier { tier, n_tiers } => write!(
                f,
                "tier index {tier} out of range ({n_tiers} tiers)"
            ),
            PlanError::BadDp { dp, m } => write!(
                f,
                "dp degree {dp} does not divide micro-batch count {m}"
            ),
            PlanError::OutOfMemory { stage, need_mb, have_mb } => write!(
                f,
                "stage {stage} needs {need_mb} MB but tier provides {have_mb} MB"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

impl Plan {
    /// Single-stage plan (pure data parallelism / LambdaML shape).
    pub fn data_parallel(dp: usize, tier: usize, n_micro_global: usize) -> Self {
        Self { cuts: vec![], dp, stage_tiers: vec![tier], n_micro_global }
    }

    pub fn n_stages(&self) -> usize {
        self.cuts.len() + 1
    }

    pub fn n_workers(&self) -> usize {
        self.n_stages() * self.dp
    }

    /// Micro-batches per worker `μ = M / d`.
    pub fn mu(&self) -> usize {
        self.n_micro_global / self.dp
    }

    /// Inclusive layer ranges `[(lo, hi)]` per stage.
    pub fn stage_ranges(&self, n_layers: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.n_stages());
        let mut lo = 0;
        for &c in &self.cuts {
            out.push((lo, c));
            lo = c + 1;
        }
        out.push((lo, n_layers - 1));
        out
    }

    /// Stage index that layer `i` belongs to.
    pub fn stage_of(&self, layer: usize) -> usize {
        self.cuts.iter().filter(|&&c| c < layer).count()
    }

    /// Total allocated memory across all workers in GB (`c_mem`, eq. (5)).
    pub fn total_mem_gb(&self, platform: &PlatformSpec) -> f64 {
        self.stage_tiers
            .iter()
            .map(|&j| platform.tier(j).mem_gb())
            .sum::<f64>()
            * self.dp as f64
    }

    /// Memory demand of one worker of `stage` in bytes — constraint (3b):
    /// `μ·â + ŝ·(4 − 2·[d==1]) + s_0`.
    pub fn stage_mem_bytes(
        &self,
        model: &ModelProfile,
        platform: &PlatformSpec,
        stage: usize,
    ) -> u64 {
        let ranges = self.stage_ranges(model.n_layers());
        let (lo, hi) = ranges[stage];
        let act = model.range_act_bytes(lo, hi);
        let params = model.range_param_bytes(lo, hi);
        let sync_copies = if self.dp == 1 { 2 } else { 4 };
        (self.mu() as u64) * act
            + params * sync_copies
            + platform.base_mem_mb * 1024 * 1024
    }

    /// Full validation against the model and platform.
    pub fn validate(
        &self,
        model: &ModelProfile,
        platform: &PlatformSpec,
    ) -> Result<(), PlanError> {
        let l = model.n_layers();
        let increasing =
            self.cuts.windows(2).all(|w| w[0] < w[1]);
        if !increasing || self.cuts.iter().any(|&c| c + 1 >= l) {
            return Err(PlanError::BadCuts { cuts: self.cuts.clone(), l });
        }
        if self.stage_tiers.len() != self.n_stages() {
            return Err(PlanError::TierLen {
                got: self.stage_tiers.len(),
                want: self.n_stages(),
            });
        }
        for &t in &self.stage_tiers {
            if t >= platform.n_tiers() {
                return Err(PlanError::BadTier {
                    tier: t,
                    n_tiers: platform.n_tiers(),
                });
            }
        }
        if self.dp == 0 || self.n_micro_global % self.dp != 0 {
            return Err(PlanError::BadDp {
                dp: self.dp,
                m: self.n_micro_global,
            });
        }
        for s in 0..self.n_stages() {
            let need = self.stage_mem_bytes(model, platform, s);
            let have = platform.tier(self.stage_tiers[s]).mem_bytes();
            if need > have {
                return Err(PlanError::OutOfMemory {
                    stage: s,
                    need_mb: need / (1024 * 1024),
                    have_mb: have / (1024 * 1024),
                });
            }
        }
        Ok(())
    }

    /// JSON form of the §3.4 decision variable — the serializable core of
    /// the plan artifact (`funcpipe plan --out plan.json`). Structural
    /// only; semantic feasibility is [`Plan::validate`]'s job.
    pub fn to_json(&self) -> Json {
        let nums = |xs: &[usize]| {
            Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
        };
        Json::obj(vec![
            ("cuts", nums(&self.cuts)),
            ("dp", Json::Num(self.dp as f64)),
            ("stage_tiers", nums(&self.stage_tiers)),
            ("n_micro_global", Json::Num(self.n_micro_global as f64)),
        ])
    }

    /// Inverse of [`Plan::to_json`]. Strict: keys outside the plan
    /// schema are errors, so a hand-edited artifact with a misplaced
    /// knob fails loudly instead of silently dropping it.
    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        j.check_keys(&["cuts", "dp", "stage_tiers", "n_micro_global"])?;
        let usizes = |key: &str| -> Result<Vec<usize>, JsonError> {
            j.field_arr(key)?
                .iter()
                .map(|v| {
                    v.as_usize().ok_or_else(|| {
                        JsonError::TypeMismatch(key.to_string(), "usize")
                    })
                })
                .collect()
        };
        Ok(Self {
            cuts: usizes("cuts")?,
            dp: j.field_usize("dp")?,
            stage_tiers: usizes("stage_tiers")?,
            n_micro_global: j.field_usize("n_micro_global")?,
        })
    }

    /// Human-readable summary ("[0..7]@4096 | [8..23]@10240, d=2, μ=8").
    pub fn describe(&self, model: &ModelProfile, platform: &PlatformSpec) -> String {
        let ranges = self.stage_ranges(model.n_layers());
        let stages: Vec<String> = ranges
            .iter()
            .zip(&self.stage_tiers)
            .map(|(&(lo, hi), &t)| {
                format!("[{lo}..{hi}]@{}MB", platform.tier(t).mem_mb)
            })
            .collect();
        format!(
            "{} | d={} μ={} workers={}",
            stages.join(" | "),
            self.dp,
            self.mu(),
            self.n_workers()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::platform::PlatformSpec;

    fn setup() -> (ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = zoo::resnet101(&p);
        (m, p)
    }

    #[test]
    fn stage_ranges_cover_all_layers() {
        let plan = Plan {
            cuts: vec![3, 9],
            dp: 2,
            stage_tiers: vec![0, 1, 2],
            n_micro_global: 8,
        };
        let ranges = plan.stage_ranges(24);
        assert_eq!(ranges, vec![(0, 3), (4, 9), (10, 23)]);
        assert_eq!(plan.n_workers(), 6);
        assert_eq!(plan.mu(), 4);
        assert_eq!(plan.stage_of(0), 0);
        assert_eq!(plan.stage_of(4), 1);
        assert_eq!(plan.stage_of(23), 2);
    }

    #[test]
    fn validation_rejects_bad_cuts() {
        let (m, p) = setup();
        let plan = Plan {
            cuts: vec![9, 3],
            dp: 1,
            stage_tiers: vec![7, 7, 7],
            n_micro_global: 4,
        };
        assert!(matches!(
            plan.validate(&m, &p),
            Err(PlanError::BadCuts { .. })
        ));
        let plan2 = Plan {
            cuts: vec![23],
            dp: 1,
            stage_tiers: vec![7, 7],
            n_micro_global: 4,
        };
        assert!(matches!(
            plan2.validate(&m, &p),
            Err(PlanError::BadCuts { .. })
        ));
    }

    #[test]
    fn validation_rejects_dp_mismatch() {
        let (m, p) = setup();
        let plan = Plan {
            cuts: vec![],
            dp: 3,
            stage_tiers: vec![7],
            n_micro_global: 4,
        };
        assert!(matches!(plan.validate(&m, &p), Err(PlanError::BadDp { .. })));
    }

    #[test]
    fn memory_constraint_3b() {
        let (m, p) = setup();
        // whole ResNet101 on one 512 MB worker with 16 micro-batches: OOM
        let plan = Plan {
            cuts: vec![],
            dp: 1,
            stage_tiers: vec![0],
            n_micro_global: 16,
        };
        assert!(matches!(
            plan.validate(&m, &p),
            Err(PlanError::OutOfMemory { .. })
        ));
        // but on the 10 GB tier it fits (170 MB params * 2 + acts)
        let plan = Plan {
            cuts: vec![],
            dp: 1,
            stage_tiers: vec![7],
            n_micro_global: 4,
        };
        plan.validate(&m, &p).unwrap();
    }

    #[test]
    fn dp_adds_sync_memory() {
        let (m, p) = setup();
        let mk = |dp| Plan {
            cuts: vec![],
            dp,
            stage_tiers: vec![7],
            n_micro_global: 8,
        };
        // d=1: 2 copies (params+grads); d=2: 4 copies (+serialization),
        // but μ halves so activations shrink
        let m1 = mk(1).stage_mem_bytes(&m, &p, 0);
        let m2 = mk(2).stage_mem_bytes(&m, &p, 0);
        let params = m.total_param_bytes();
        let act = m.total_act_bytes();
        let s0 = p.base_mem_mb * 1024 * 1024;
        assert_eq!(m1, 8 * act + 2 * params + s0);
        assert_eq!(m2, 4 * act + 4 * params + s0);
    }

    #[test]
    fn json_roundtrip() {
        let plan = Plan {
            cuts: vec![3, 9],
            dp: 4,
            stage_tiers: vec![0, 5, 7],
            n_micro_global: 16,
        };
        let j = plan.to_json();
        assert_eq!(Plan::from_json(&j).unwrap(), plan);
        // and through text
        let reparsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(Plan::from_json(&reparsed).unwrap(), plan);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let missing = crate::util::json::Json::parse(r#"{"dp": 2}"#).unwrap();
        assert!(Plan::from_json(&missing).is_err());
        let bad_type = crate::util::json::Json::parse(
            r#"{"cuts": [1.5], "dp": 2, "stage_tiers": [0], "n_micro_global": 4}"#,
        )
        .unwrap();
        assert!(Plan::from_json(&bad_type).is_err());
        let unknown_key = crate::util::json::Json::parse(
            r#"{"cuts": [], "dp": 2, "stage_tiers": [0],
                "n_micro_global": 4, "mu": 2}"#,
        )
        .unwrap();
        assert!(Plan::from_json(&unknown_key).is_err());
    }

    #[test]
    fn describe_contains_tiers() {
        let (m, p) = setup();
        let plan = Plan {
            cuts: vec![11],
            dp: 2,
            stage_tiers: vec![3, 7],
            n_micro_global: 8,
        };
        let d = plan.describe(&m, &p);
        assert!(d.contains("3072MB"), "{d}");
        assert!(d.contains("10240MB"), "{d}");
        assert!(d.contains("d=2"), "{d}");
    }
}
