//! Per-layer profile — the quantities the *Model Profiler* gathers (§3.1
//! step 3) and the §3.4 notation table consumes.

/// One model layer's measured/derived characteristics.
///
/// Compute times are *per micro-batch* and indexed by memory-tier: entry
/// `j` is the time on a worker with `PlatformSpec::tiers[j]` resources
/// (`T_fc^{i,j}` / `T_bc^{i,j}` in the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    /// Parameter bytes `s_i`.
    pub param_bytes: u64,
    /// Activation memory per micro-batch `a_i` (bytes).
    pub act_bytes: u64,
    /// Output (boundary activation) bytes per micro-batch `o_i`.
    pub out_bytes: u64,
    /// Gradient bytes flowing to the previous layer per micro-batch `g_i`.
    pub grad_bytes: u64,
    /// Forward compute seconds per micro-batch, per memory tier.
    pub fwd_s: Vec<f64>,
    /// Backward compute seconds per micro-batch, per memory tier.
    pub bwd_s: Vec<f64>,
}

impl LayerProfile {
    /// Scale all compute times by `f` (used when calibrating profiles).
    pub fn scale_compute(&mut self, f: f64) {
        for t in self.fwd_s.iter_mut().chain(self.bwd_s.iter_mut()) {
            *t *= f;
        }
    }
}

/// A profiled model: ordered layers plus bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: String,
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_param_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.param_bytes).sum()
    }

    pub fn total_act_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.act_bytes).sum()
    }

    /// Total forward compute time at tier `j` (one micro-batch).
    pub fn total_fwd_s(&self, tier: usize) -> f64 {
        self.layers.iter().map(|l| l.fwd_s[tier]).sum()
    }

    pub fn total_bwd_s(&self, tier: usize) -> f64 {
        self.layers.iter().map(|l| l.bwd_s[tier]).sum()
    }

    /// Param bytes of the contiguous layer range `[lo, hi]` inclusive —
    /// the hat/tilde accumulation of §3.4 over one partition.
    pub fn range_param_bytes(&self, lo: usize, hi: usize) -> u64 {
        self.layers[lo..=hi].iter().map(|l| l.param_bytes).sum()
    }

    pub fn range_act_bytes(&self, lo: usize, hi: usize) -> u64 {
        self.layers[lo..=hi].iter().map(|l| l.act_bytes).sum()
    }

    pub fn range_fwd_s(&self, lo: usize, hi: usize, tier: usize) -> f64 {
        self.layers[lo..=hi].iter().map(|l| l.fwd_s[tier]).sum()
    }

    pub fn range_bwd_s(&self, lo: usize, hi: usize, tier: usize) -> f64 {
        self.layers[lo..=hi].iter().map(|l| l.bwd_s[tier]).sum()
    }

    /// Validate internal consistency (tier vector lengths line up, sizes
    /// are nonzero where they must be).
    pub fn validate(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err("model has no layers".into());
        }
        let n_tiers = self.layers[0].fwd_s.len();
        for (i, l) in self.layers.iter().enumerate() {
            if l.fwd_s.len() != n_tiers || l.bwd_s.len() != n_tiers {
                return Err(format!("layer {i} tier-vector length mismatch"));
            }
            if l.fwd_s.iter().chain(l.bwd_s.iter()).any(|&t| t < 0.0) {
                return Err(format!("layer {i} has negative compute time"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(p: u64, f: f64) -> LayerProfile {
        LayerProfile {
            name: "l".into(),
            param_bytes: p,
            act_bytes: 10,
            out_bytes: 5,
            grad_bytes: 5,
            fwd_s: vec![f, f / 2.0],
            bwd_s: vec![2.0 * f, f],
        }
    }

    #[test]
    fn totals_and_ranges() {
        let m = ModelProfile {
            name: "m".into(),
            layers: vec![layer(100, 1.0), layer(200, 2.0), layer(300, 3.0)],
        };
        assert_eq!(m.total_param_bytes(), 600);
        assert_eq!(m.range_param_bytes(1, 2), 500);
        assert!((m.total_fwd_s(0) - 6.0).abs() < 1e-12);
        assert!((m.range_bwd_s(0, 1, 1) - 3.0).abs() < 1e-12);
        m.validate().unwrap();
    }

    #[test]
    fn validation_catches_mismatch() {
        let mut m = ModelProfile {
            name: "m".into(),
            layers: vec![layer(1, 1.0)],
        };
        m.layers[0].bwd_s = vec![1.0];
        assert!(m.validate().is_err());
    }
}
