//! Direct solver over the paper's binary decision variables — the
//! stand-in for Gurobi on the §3.4 program (App. C's MIQP after
//! linearization has exactly this solution set; see DESIGN.md §7).
//!
//! Variables: `x_i ∈ {0,1}` (cut after layer i), `y_k` one-hot over the
//! data-parallel options, `z_{i,j}` one-hot memory tier per layer with the
//! consistency constraint (3c) (`m_i = m_{i−1}` unless `x_{i−1}=1`).
//! The solver enumerates assignments in variable order x₁, z₁, x₂, z₂, …
//! with constraint propagation: (3c) forces `z` inside a stage, (3b)
//! prunes infeasible prefixes, and an admissible objective bound prunes
//! the rest. Exact, but slower than [`optimizer`](super::optimizer) —
//! used to certify it (they must return identical optima).

use crate::model::{ModelProfile, Plan};
use crate::planner::perf_model::PerfModel;
use crate::platform::PlatformSpec;

/// Result of a MIQP solve.
#[derive(Debug, Clone)]
pub struct MiqpSolution {
    pub plan: Plan,
    pub objective: f64,
    pub nodes: u64,
}

/// The classic struct API over the shared [`solve_with`] core (the
/// `miqp` registry strategy calls the core directly against a shared
/// [`PerfModel`]).
pub struct MiqpSolver<'a> {
    pub perf: PerfModel<'a>,
    pub dp_options: Vec<usize>,
}

impl<'a> MiqpSolver<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        Self {
            perf: PerfModel::new(model, platform),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
        }
    }

    pub fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<MiqpSolution> {
        solve_with(&self.perf, &self.dp_options, u64::MAX, n_micro_global, alpha)
    }
}

/// The direct binary-variable solver, independent of the struct
/// wrapper: enumerate y (one-hot over d), then x and z jointly with
/// constraint propagation. `node_budget` caps the enumeration (anytime
/// behaviour, `u64::MAX` = exact).
pub fn solve_with(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    node_budget: u64,
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<MiqpSolution> {
    let m = perf.model;
    let l = m.n_layers();
    let mut nodes = 0u64;
    let mut best: Option<(f64, Plan)> = None;

    for &d in dp_options {
        if d == 0 || n_micro_global % d != 0 {
            continue;
        }
        // enumerate x and z jointly, layer by layer. State: current
        // stage start and its tier (z is constant within a stage by
        // (3c)).
        let mut x = vec![false; l.saturating_sub(1)];
        enumerate(
            perf,
            node_budget,
            0,
            None,
            &mut x,
            &mut Vec::new(),
            d,
            n_micro_global,
            alpha,
            &mut best,
            &mut nodes,
        );
    }
    best.map(|(objective, plan)| MiqpSolution { plan, objective, nodes })
}

#[allow(clippy::too_many_arguments)]
fn enumerate(
    perf: &PerfModel<'_>,
    node_budget: u64,
    layer: usize,
    cur_tier: Option<(usize, usize)>, // (stage start layer, tier)
    x: &mut Vec<bool>,
    tiers: &mut Vec<usize>,
    d: usize,
    n_micro_global: usize,
    alpha: (f64, f64),
    best: &mut Option<(f64, Plan)>,
    nodes: &mut u64,
) {
    let m = perf.model;
    let p = perf.platform;
    let l = m.n_layers();
    *nodes += 1;
    if *nodes > node_budget {
        return;
    }

    // choose z for `layer`: free at a stage start, forced otherwise
    let tier_choices: Vec<usize> = match cur_tier {
        None => (0..p.n_tiers()).collect(),
        Some((_, t)) => vec![t],
    };
    for tier in tier_choices {
        let stage_start = cur_tier.map(|(s, _)| s).unwrap_or(layer);
        // (3b) check on the stage prefix [stage_start..=layer]
        let mu = n_micro_global / d;
        let act = m.range_act_bytes(stage_start, layer);
        let params = m.range_param_bytes(stage_start, layer);
        let copies = if d == 1 { 2 } else { 4 };
        let need = (mu as u64) * act
            + params * copies
            + p.base_mem_mb * 1024 * 1024;
        if need > p.tier(tier).mem_bytes() {
            continue;
        }

        if layer == l - 1 {
            // complete assignment — close final stage
            tiers.push(tier);
            let cuts: Vec<usize> = (0..l - 1).filter(|&i| x[i]).collect();
            let plan = Plan {
                cuts,
                dp: d,
                stage_tiers: tiers.clone(),
                n_micro_global,
            };
            if plan.validate(m, p).is_ok() {
                let pf = perf.evaluate(&plan);
                let j = alpha.0 * pf.c_iter + alpha.1 * pf.t_iter;
                if best.as_ref().map(|(b, _)| j < *b).unwrap_or(true) {
                    *best = Some((j, plan));
                }
            }
            tiers.pop();
            continue;
        }

        // branch on x[layer]
        for cut in [true, false] {
            x[layer] = cut;
            if cut {
                tiers.push(tier);
                enumerate(
                    perf,
                    node_budget,
                    layer + 1,
                    None,
                    x,
                    tiers,
                    d,
                    n_micro_global,
                    alpha,
                    best,
                    nodes,
                );
                tiers.pop();
            } else {
                enumerate(
                    perf,
                    node_budget,
                    layer + 1,
                    Some((stage_start, tier)),
                    x,
                    tiers,
                    d,
                    n_micro_global,
                    alpha,
                    best,
                    nodes,
                );
            }
            x[layer] = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};
    use crate::planner::optimizer::CoOptimizer;

    /// The two exact solvers must agree — this certifies the B&B.
    #[test]
    fn miqp_certifies_branch_and_bound() {
        let p = PlatformSpec::aws_lambda();
        for name in ["resnet101", "bert-large"] {
            let m = merge_layers(
                &zoo::by_name(name, &p).unwrap(),
                5,
                MergeCriterion::Compute,
            );
            let alpha = (1.0, 1e-4);
            let mut opt = CoOptimizer::new(&m, &p);
            opt.dp_options = vec![1, 2, 4];
            let mut miqp = MiqpSolver::new(&m, &p);
            miqp.dp_options = vec![1, 2, 4];

            let (_, perf, _) = opt.solve(8, alpha).unwrap();
            let j_bb = alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;
            let sol = miqp.solve(8, alpha).unwrap();
            assert!(
                (sol.objective - j_bb).abs() < 1e-9 * j_bb.max(1.0),
                "{name}: miqp {} vs b&b {}",
                sol.objective,
                j_bb
            );
        }
    }

    #[test]
    fn miqp_respects_memory() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::amoebanet_d36(&p),
            4,
            MergeCriterion::Compute,
        );
        let mut s = MiqpSolver::new(&m, &p);
        s.dp_options = vec![1, 2];
        let sol = s.solve(8, (1.0, 1e-4)).unwrap();
        sol.plan.validate(&m, &p).unwrap();
    }
}
