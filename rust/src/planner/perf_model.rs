//! The closed-form pipeline performance model of §3.4.2 and Appendix B:
//! given a [`Plan`], predict iteration time `t_iter` (eq. (7)) and cost
//! `c_iter` (eq. (6)).
//!
//! Because partition boundaries can only fall between (merged) layers,
//! every per-layer quantity with a hat/tilde accumulator in the paper is
//! evaluated here directly per *stage* — numerically identical, and it
//! keeps `evaluate` allocation-free on the planner's hot path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::collective::{sync_time_chunked, SyncAlgorithm};
use crate::model::{ModelProfile, Plan};
use crate::platform::PlatformSpec;
use crate::replan::MeasuredProfile;

/// Per-stage terms the model derives from a `(layer-range, tier)` pair:
/// compute times at that tier plus the byte totals every communication
/// term is a closed-form function of. Everything downstream — sync time
/// for any `dp`, memory feasibility, the optimizer's bounds — is O(1)
/// arithmetic over these, so this is exactly the unit worth memoizing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTerms {
    /// Forward compute of one micro-batch, seconds (un-β-scaled).
    pub fwd_s: f64,
    /// Backward compute of one micro-batch, seconds (un-β-scaled).
    pub bwd_s: f64,
    /// Parameter bytes of the range (the sync-traffic term of eq. (9)).
    pub param_bytes: u64,
    /// Activation bytes of one micro-batch (constraint (3b)).
    pub act_bytes: u64,
}

/// Shard count of [`StageCache`]. A power of two comfortably above the
/// worker-pool sizes we run (`exec::pool_size()` is ~cores), so racing
/// strategies, scoring workers, and B&B packets rarely contend on the
/// same lock.
const CACHE_SHARDS: usize = 16;

/// Memoization of [`StageTerms`] keyed by `(lo, hi, tier, overlay
/// epoch)`, with hit/miss counters.
///
/// `Optimizer::solve`'s B&B loop evaluates thousands of candidate plans
/// whose stages repeat the same few hundred `(layer-range, tier)`
/// combinations; before the cache every node recomputed the O(range)
/// layer sums from scratch. The `dp` dimension of the key collapses
/// because every dp-dependent term (eq. (9) sync, replica memory) is
/// O(1) arithmetic over the cached bytes. Interior-mutable so the hot
/// path keeps its `&self` signature, and `Sync` so `plan --strategy
/// all`, the parallel scoring work-queue, and B&B work packets can all
/// share ONE warm cache: entries are pure functions of the key, so
/// concurrent misses insert identical values and results never depend
/// on thread interleaving (only the hit/miss counters can drift by the
/// occasional double-miss). The map is **sharded by key hash** across
/// [`CACHE_SHARDS`] mutexes — one global lock measurably serialized
/// the racing strategies and the PR 8 worker pool.
///
/// The fourth key word is the **overlay epoch**: 0 for the profile-only
/// model, and the [`MeasuredProfile::epoch`] of a mid-run re-plan
/// otherwise. Distinct epochs occupy disjoint key spaces, so a warm
/// cache can be reused across re-plans without ever serving a term
/// computed under a stale measured profile.
#[derive(Debug)]
pub struct StageCache {
    shards:
        [Mutex<HashMap<(usize, usize, usize, u64), StageTerms>>; CACHE_SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

/// FNV-1a over the four key words — cheap, deterministic, and spreads
/// the near-contiguous `(lo, hi, tier, epoch)` tuples well across
/// shards.
fn shard_of(key: &(usize, usize, usize, u64)) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in [key.0 as u64, key.1 as u64, key.2 as u64, key.3] {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) % CACHE_SHARDS
}

impl Default for StageCache {
    fn default() -> Self {
        Self {
            shards: std::array::from_fn(|_| Mutex::new(HashMap::new())),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

impl Clone for StageCache {
    fn clone(&self) -> Self {
        Self {
            shards: std::array::from_fn(|i| {
                Mutex::new(self.shards[i].lock().unwrap().clone())
            }),
            hits: AtomicU64::new(self.hits.load(Ordering::Relaxed)),
            misses: AtomicU64::new(self.misses.load(Ordering::Relaxed)),
        }
    }
}

impl StageCache {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of lookups served from the cache (0.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits() + self.misses();
        if total == 0 {
            0.0
        } else {
            self.hits() as f64 / total as f64
        }
    }

    /// Distinct `(lo, hi, tier, epoch)` entries currently cached.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Drop entries and counters (between unrelated sweeps in benches).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    fn get_or_insert(
        &self,
        key: (usize, usize, usize, u64),
        compute: impl FnOnce() -> StageTerms,
    ) -> StageTerms {
        let shard = &self.shards[shard_of(&key)];
        if let Some(t) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *t;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t = compute();
        shard.lock().unwrap().insert(key, t);
        t
    }
}

/// Evaluated performance of one plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanPerf {
    /// Iteration wall time, seconds (eq. (7)).
    pub t_iter: f64,
    /// Iteration cost, dollars (eq. (6)).
    pub c_iter: f64,
    /// Forward-pipeline completion time `t_f`.
    pub t_fwd: f64,
    /// `max_i (t_b^i + t_s^i)`.
    pub t_bwd_sync: f64,
    /// Breakdown for Fig. 6: pure compute | pipeline flush (bubbles +
    /// boundary transfers) | intra-stage synchronization.
    pub compute_s: f64,
    pub flush_s: f64,
    pub sync_s: f64,
    /// Total allocated memory, GB (`c_mem` of eq. (5), already × d).
    pub total_mem_gb: f64,
}

impl PlanPerf {
    /// Training throughput in samples/second for a given global batch.
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.t_iter
    }
}

/// The performance model, parameterized by model profile + platform +
/// sync algorithm (γ, δ in eq. (9)).
#[derive(Debug, Clone)]
pub struct PerfModel<'a> {
    pub model: &'a ModelProfile,
    pub platform: &'a PlatformSpec,
    pub sync_alg: SyncAlgorithm,
    /// Chunk size of the storage collectives in bytes; 0 = unchunked.
    /// Adds the per-chunk latency term of
    /// [`sync_time_chunked`](crate::collective::sync_time_chunked) to the
    /// synchronization model, so plans are costed with the same knob the
    /// trainer runs with.
    pub chunk_bytes: usize,
    /// Measured mid-run overrides (compute multipliers + link
    /// bandwidth) substituted for the profiled values during an elastic
    /// re-plan. `None` = plan purely from the profile.
    overlay: Option<MeasuredProfile>,
    /// Memoized per-stage terms — the planner hot loop's cache.
    cache: StageCache,
}

impl<'a> PerfModel<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        Self {
            model,
            platform,
            sync_alg: SyncAlgorithm::PipelinedScatterReduce,
            chunk_bytes: 0,
            overlay: None,
            cache: StageCache::default(),
        }
    }

    /// The memoized per-stage terms of the range `[lo, hi]` at `tier`.
    /// First lookup computes the O(range) layer sums; every further
    /// plan sharing the stage is an O(1) hit (counters on
    /// [`PerfModel::cache`]). Under a measured overlay each layer's
    /// compute is scaled by its observed multiplier, and the cache key
    /// carries the overlay epoch so profile-only and per-re-plan terms
    /// never mix.
    pub fn stage_terms(&self, lo: usize, hi: usize, tier: usize) -> StageTerms {
        let epoch = self.overlay_epoch();
        self.cache.get_or_insert((lo, hi, tier, epoch), || {
            let (fwd_s, bwd_s) = match &self.overlay {
                None => (
                    self.model.range_fwd_s(lo, hi, tier),
                    self.model.range_bwd_s(lo, hi, tier),
                ),
                Some(o) => {
                    let mut fwd = 0.0;
                    let mut bwd = 0.0;
                    for (l, layer) in
                        self.model.layers[lo..=hi].iter().enumerate()
                    {
                        let m = o.mult_for_layer(lo + l);
                        fwd += layer.fwd_s[tier] * m;
                        bwd += layer.bwd_s[tier] * m;
                    }
                    (fwd, bwd)
                }
            };
            StageTerms {
                fwd_s,
                bwd_s,
                param_bytes: self.model.range_param_bytes(lo, hi),
                act_bytes: self.model.range_act_bytes(lo, hi),
            }
        })
    }

    /// Cache telemetry (hit/miss counters, entry count).
    pub fn cache(&self) -> &StageCache {
        &self.cache
    }

    /// Substitute measured per-layer compute multipliers and link
    /// bandwidth for the profiled values (elastic re-planning). Epoch 0
    /// is reserved for the profile-only model and is normalized up.
    pub fn with_overlay(mut self, mut overlay: MeasuredProfile) -> Self {
        overlay.epoch = overlay.epoch.max(1);
        self.overlay = Some(overlay);
        self
    }

    /// The active overlay's epoch (0 = profile-only, no overlay).
    pub fn overlay_epoch(&self) -> u64 {
        self.overlay.as_ref().map(|o| o.epoch).unwrap_or(0)
    }

    pub fn with_sync(mut self, alg: SyncAlgorithm) -> Self {
        self.sync_alg = alg;
        self
    }

    pub fn with_chunk_bytes(mut self, chunk_bytes: usize) -> Self {
        self.chunk_bytes = chunk_bytes;
        self
    }

    /// Fast path for optimizer inner loops: only (t_iter, c_iter), one
    /// model pass, no breakdown (the breakdown needs a second
    /// communication-free pass).
    pub fn quick(&self, plan: &Plan) -> (f64, f64) {
        let (t_iter, _, _) = self.eval_inner(plan, false);
        let total_mem_gb = plan.total_mem_gb(self.platform);
        let c_iter = self.platform.price_per_gb_s * total_mem_gb * t_iter;
        (t_iter, c_iter)
    }

    /// Full evaluation (assumes `plan.validate()` passed).
    pub fn evaluate(&self, plan: &Plan) -> PlanPerf {
        let full = self.eval_inner(plan, false);
        let nocomm = self.eval_inner(plan, true);
        let compute_s = nocomm.0;
        let t_iter_nosync = full.2;
        let t_iter = full.0;
        let flush_s = (t_iter_nosync - compute_s).max(0.0);
        let sync_s = (t_iter - t_iter_nosync).max(0.0);

        let total_mem_gb = plan.total_mem_gb(self.platform);
        let c_iter = self.platform.price_per_gb_s * total_mem_gb * t_iter;
        PlanPerf {
            t_iter,
            c_iter,
            t_fwd: full.1,
            t_bwd_sync: t_iter - full.1,
            compute_s,
            flush_s,
            sync_s,
            total_mem_gb,
        }
    }

    /// Returns (t_iter, t_f, t_iter_without_sync).
    ///
    /// `compute_only`: zero out communication (infinite bandwidth, zero
    /// latency, β=1) — used for the Fig. 6 breakdown.
    fn eval_inner(&self, plan: &Plan, compute_only: bool) -> (f64, f64, f64) {
        let m = self.model;
        let p = self.platform;
        let ranges = plan.stage_ranges(m.n_layers());
        let s_cnt = ranges.len();
        let mu = plan.mu() as f64;
        let n_workers = plan.n_workers();
        let t_lat = if compute_only { 0.0 } else { p.storage.latency_s };
        // β applies only when compute overlaps communication
        let has_comm = !compute_only && (s_cnt > 1 || plan.dp > 1);
        let beta = if has_comm { p.beta } else { 1.0 };

        // measured link bandwidth substitutes for the profiled value
        // under an overlay (a straggling NIC slows every transfer term)
        let link_mult = match (&self.overlay, compute_only) {
            (Some(o), false) => o.bandwidth_mult,
            _ => 1.0,
        };
        let bw = |tier: usize| -> f64 {
            if compute_only {
                f64::INFINITY
            } else {
                p.effective_bandwidth(tier, n_workers) * link_mult
            }
        };

        // per-stage compute times (one micro-batch), memoized across
        // plans sharing the same (range, tier) stage
        let mut fc = Vec::with_capacity(s_cnt);
        let mut bc = Vec::with_capacity(s_cnt);
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            let terms = self.stage_terms(lo, hi, plan.stage_tiers[s]);
            fc.push(beta * terms.fwd_s);
            bc.push(beta * terms.bwd_s);
        }

        // boundary transfer times: boundary b sits between stage b and b+1
        let nb = s_cnt - 1;
        let mut fu = vec![0.0; nb];
        let mut fd = vec![0.0; nb];
        let mut bu = vec![0.0; nb];
        let mut bd = vec![0.0; nb];
        for b in 0..nb {
            let out_bytes = m.layers[ranges[b].1].out_bytes as f64;
            let grad_bytes = m.layers[ranges[b + 1].0].grad_bytes as f64;
            fu[b] = out_bytes / bw(plan.stage_tiers[b]) + t_lat;
            fd[b] = out_bytes / bw(plan.stage_tiers[b + 1]) + t_lat;
            bu[b] = grad_bytes / bw(plan.stage_tiers[b + 1]) + t_lat;
            bd[b] = grad_bytes / bw(plan.stage_tiers[b]) + t_lat;
        }

        // ---- forward: t_f = t_f^0 + (μ-1)·Δ_f ---------------------------
        let t_f0: f64 = fc.iter().sum::<f64>()
            + fu.iter().sum::<f64>()
            + fd.iter().sum::<f64>();
        let delta_f = fc
            .iter()
            .chain(fu.iter())
            .chain(fd.iter())
            .cloned()
            .fold(0.0, f64::max);
        let t_f = t_f0 + (mu - 1.0) * delta_f;

        // ---- backward (App. B): t_b^s per stage --------------------------
        // suffix sums/maxes over stages >= s
        let mut t_iter_max = f64::NEG_INFINITY;
        let mut t_iter_nosync_max = f64::NEG_INFINITY;
        for s in 0..s_cnt {
            let mut sum = 0.0;
            let mut delta_b: f64 = 0.0;
            for s2 in s..s_cnt {
                sum += bc[s2];
                delta_b = delta_b.max(bc[s2]);
            }
            for b in s..nb {
                sum += bu[b] + bd[b];
                delta_b = delta_b.max(bu[b]).max(bd[b]);
            }
            let t_b = sum + (mu - 1.0) * delta_b;

            // sync of this stage's replicas (eq. (9))
            let t_s = if compute_only || plan.dp == 1 {
                0.0
            } else {
                let (lo, hi) = ranges[s];
                let bytes = self
                    .stage_terms(lo, hi, plan.stage_tiers[s])
                    .param_bytes as f64;
                sync_time_chunked(
                    self.sync_alg,
                    bytes,
                    plan.dp,
                    bw(plan.stage_tiers[s]),
                    p.storage.latency_s,
                    self.chunk_bytes,
                )
            };
            t_iter_max = t_iter_max.max(t_b + t_s);
            t_iter_nosync_max = t_iter_nosync_max.max(t_b);
        }

        (t_f + t_iter_max, t_f, t_f + t_iter_nosync_max)
    }

    /// The weighted objective (3a): `α1·c_iter + α2·t_iter`.
    pub fn objective(&self, plan: &Plan, alpha: (f64, f64)) -> f64 {
        let perf = self.evaluate(plan);
        alpha.0 * perf.c_iter + alpha.1 * perf.t_iter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn fixture() -> (ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        (zoo::amoebanet_d18(&p), p)
    }

    fn plan_1w(m: &ModelProfile) -> Plan {
        let _ = m;
        Plan { cuts: vec![], dp: 1, stage_tiers: vec![7], n_micro_global: 4 }
    }

    #[test]
    fn single_worker_has_no_comm() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let perf = pm.evaluate(&plan_1w(&m));
        assert!(perf.sync_s == 0.0);
        assert!(perf.flush_s.abs() < 1e-9);
        // t_iter == μ * (fwd+bwd) at top tier
        let per_micro = m.total_fwd_s(7) + m.total_bwd_s(7);
        assert!((perf.t_iter - 4.0 * per_micro).abs() < 1e-6);
    }

    #[test]
    fn dp_adds_sync_time() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let dp1 = pm.evaluate(&Plan {
            cuts: vec![],
            dp: 1,
            stage_tiers: vec![7],
            n_micro_global: 8,
        });
        let dp2 = pm.evaluate(&Plan {
            cuts: vec![],
            dp: 2,
            stage_tiers: vec![7],
            n_micro_global: 8,
        });
        assert_eq!(dp1.sync_s, 0.0);
        assert!(dp2.sync_s > 1.0, "sync {:.2}", dp2.sync_s);
        // dp halves μ so compute halves
        assert!((dp2.compute_s - dp1.compute_s / 2.0).abs() < 1e-6);
    }

    #[test]
    fn pipelined_sync_beats_plain() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![8],
            dp: 4,
            stage_tiers: vec![7, 7],
            n_micro_global: 16,
        };
        let piped = PerfModel::new(&m, &p).evaluate(&plan);
        let plain = PerfModel::new(&m, &p)
            .with_sync(SyncAlgorithm::ScatterReduce)
            .evaluate(&plan);
        assert!(piped.t_iter < plain.t_iter);
        assert!(piped.sync_s < plain.sync_s);
    }

    #[test]
    fn partitioning_reduces_sync_vs_data_parallel() {
        // the paper's key insight: partition => smaller per-stage grads
        // => less sync traffic than pure DP
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let pure_dp = Plan {
            cuts: vec![],
            dp: 4,
            stage_tiers: vec![7; 1],
            n_micro_global: 16,
        };
        let pipe = Plan {
            cuts: vec![5, 11],
            dp: 4,
            stage_tiers: vec![7, 7, 7],
            n_micro_global: 16,
        };
        let a = pm.evaluate(&pure_dp);
        let b = pm.evaluate(&pipe);
        assert!(b.sync_s < a.sync_s, "{} !< {}", b.sync_s, a.sync_s);
    }

    #[test]
    fn mu_scaling_is_linear_in_micro_batches() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let mk = |mm: usize| Plan {
            cuts: vec![8],
            dp: 1,
            stage_tiers: vec![7, 7],
            n_micro_global: mm,
        };
        let a = pm.evaluate(&mk(4));
        let b = pm.evaluate(&mk(8));
        // t grows by (μb-μa)·(Δf + Δb) — strictly increasing, sub-2x
        assert!(b.t_iter > a.t_iter);
        assert!(b.t_iter < 2.0 * a.t_iter);
    }

    #[test]
    fn chunking_knob_adds_latency_but_preserves_transfer() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![8],
            dp: 4,
            stage_tiers: vec![7, 7],
            n_micro_global: 16,
        };
        let base = PerfModel::new(&m, &p).evaluate(&plan);
        let chunked = PerfModel::new(&m, &p)
            .with_chunk_bytes(1 << 20)
            .evaluate(&plan);
        // more storage ops -> more sync latency, nothing else moves
        assert!(chunked.sync_s > base.sync_s);
        assert!((chunked.compute_s - base.compute_s).abs() < 1e-9);
        // huge chunks converge back to the unchunked model
        let coarse = PerfModel::new(&m, &p)
            .with_chunk_bytes(1 << 30)
            .evaluate(&plan);
        assert!((coarse.t_iter - base.t_iter).abs() < 1e-9);
    }

    #[test]
    fn cost_matches_eq6() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let plan = Plan {
            cuts: vec![8],
            dp: 2,
            stage_tiers: vec![3, 7],
            n_micro_global: 8,
        };
        let perf = pm.evaluate(&plan);
        let mem_gb = 2.0 * (3072.0 + 10240.0) / 1024.0;
        assert!((perf.total_mem_gb - mem_gb).abs() < 1e-9);
        assert!(
            (perf.c_iter - p.price_per_gb_s * mem_gb * perf.t_iter).abs()
                < 1e-12
        );
    }

    #[test]
    fn breakdown_sums_to_t_iter() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let plan = Plan {
            cuts: vec![5, 11],
            dp: 2,
            stage_tiers: vec![4, 5, 7],
            n_micro_global: 16,
        };
        let perf = pm.evaluate(&plan);
        let total = perf.compute_s + perf.flush_s + perf.sync_s;
        assert!(
            (total - perf.t_iter).abs() < 1e-6,
            "{total} vs {}",
            perf.t_iter
        );
    }

    #[test]
    fn stage_cache_hits_and_preserves_results() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![5, 11],
            dp: 2,
            stage_tiers: vec![4, 5, 7],
            n_micro_global: 16,
        };
        let cold = PerfModel::new(&m, &p);
        let first = cold.evaluate(&plan);
        assert!(cold.cache().misses() > 0);
        let misses_after_first = cold.cache().misses();
        let second = cold.evaluate(&plan);
        // identical plan: every stage term is a hit, results identical
        assert_eq!(cold.cache().misses(), misses_after_first);
        assert!(cold.cache().hits() > 0);
        assert_eq!(first, second);
        // a fresh model agrees with the warmed cache bit-for-bit
        let fresh = PerfModel::new(&m, &p).evaluate(&plan);
        assert_eq!(first, fresh);
    }

    #[test]
    fn stage_cache_counters_reset() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        pm.evaluate(&plan_1w(&m));
        assert!(!pm.cache().is_empty());
        pm.cache().clear();
        assert!(pm.cache().is_empty());
        assert_eq!((pm.cache().hits(), pm.cache().misses()), (0, 0));
        assert_eq!(pm.cache().hit_rate(), 0.0);
    }

    #[test]
    fn measured_overlay_scales_compute_and_bandwidth() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![8],
            dp: 2,
            stage_tiers: vec![7, 7],
            n_micro_global: 16,
        };
        let base = PerfModel::new(&m, &p).evaluate(&plan);
        // a 2x compute slowdown on every layer at least doubles compute
        let slow = MeasuredProfile {
            epoch: 1,
            compute_mult: vec![2.0; m.n_layers()],
            bandwidth_mult: 1.0,
        };
        let slowed =
            PerfModel::new(&m, &p).with_overlay(slow).evaluate(&plan);
        assert!(
            (slowed.compute_s - 2.0 * base.compute_s).abs() < 1e-9,
            "{} vs {}",
            slowed.compute_s,
            base.compute_s
        );
        assert!(slowed.t_iter > base.t_iter);
        // halved link bandwidth slows sync, leaves compute untouched
        let slow_net = MeasuredProfile {
            epoch: 1,
            compute_mult: vec![1.0; m.n_layers()],
            bandwidth_mult: 0.5,
        };
        let netted =
            PerfModel::new(&m, &p).with_overlay(slow_net).evaluate(&plan);
        assert!((netted.compute_s - base.compute_s).abs() < 1e-9);
        assert!(netted.sync_s > base.sync_s);
    }

    #[test]
    fn overlay_epochs_never_leak_stale_cache_entries() {
        let (m, p) = fixture();
        let plan = Plan {
            cuts: vec![5, 11],
            dp: 2,
            stage_tiers: vec![4, 5, 7],
            n_micro_global: 16,
        };
        let pm = PerfModel::new(&m, &p);
        let base = pm.evaluate(&plan);
        // warm the cache under an epoch-1 overlay with a 3x slowdown
        let pm_slow = pm.clone().with_overlay(MeasuredProfile {
            epoch: 1,
            compute_mult: vec![3.0; m.n_layers()],
            bandwidth_mult: 1.0,
        });
        let slow = pm_slow.evaluate(&plan);
        assert!(slow.t_iter > base.t_iter);
        // an epoch-2 identity overlay over the SAME warm cache must
        // reproduce the profile-only result exactly — stale epoch-1
        // terms cannot leak across the epoch boundary
        let pm_back = pm_slow.clone().with_overlay(MeasuredProfile {
            epoch: 2,
            compute_mult: vec![1.0; m.n_layers()],
            bandwidth_mult: 1.0,
        });
        let back = pm_back.evaluate(&plan);
        assert_eq!(back, base);
        // epoch 0 is reserved: with_overlay normalizes it up so an
        // overlay can never collide with the profile-only key space
        let pm_zero = pm.clone().with_overlay(MeasuredProfile {
            epoch: 0,
            compute_mult: vec![2.0; m.n_layers()],
            bandwidth_mult: 1.0,
        });
        assert_eq!(pm_zero.overlay_epoch(), 1);
    }

    #[test]
    fn bigger_tier_is_faster_per_stage() {
        let (m, p) = fixture();
        let pm = PerfModel::new(&m, &p);
        let lo = pm.evaluate(&Plan {
            cuts: vec![8],
            dp: 1,
            stage_tiers: vec![4, 4],
            n_micro_global: 8,
        });
        let hi = pm.evaluate(&Plan {
            cuts: vec![8],
            dp: 1,
            stage_tiers: vec![7, 7],
            n_micro_global: 8,
        });
        assert!(hi.t_iter < lo.t_iter);
    }
}
