//! The one `Planner` API over the five co-optimization solvers.
//!
//! Historically every solver had a bespoke struct entrypoint
//! (`CoOptimizer::solve`, `MiqpSolver::solve`, `BayesOpt::solve`,
//! `Tpdmp::solve`, plus the `pareto` weight sweep) and callers hardcoded
//! one of them. This module is the planning layer's analogue of the
//! `Experiment` session API and the `simcore` engine unification: ONE
//! request type goes in, ONE outcome type comes out, and the solvers
//! live behind a string-keyed registry:
//!
//! * [`PlanRequest`] — micro-batch budget, weight sweep, dp options,
//!   node/time budgets, and an optional scenario-robustness spec;
//! * [`Planner`] — the strategy trait: solve a request against a
//!   (possibly shared) [`PerfModel`];
//! * [`strategy_by_name`] / [`STRATEGIES`] — the registry: `bnb`
//!   (branch-and-bound, the default), `miqp` (direct binary-variable
//!   solver), `bayes` (GP + expected improvement), `tpdmp` (§5.6
//!   throughput-max baseline), `sweep` (balanced-partition × uniform
//!   tier × dp configuration grid);
//! * [`solve_request`] — look up, solve, and (when requested) re-score
//!   the candidates under seeded simcore scenario lenses;
//! * [`race`] — run several strategies in parallel threads over ONE
//!   shared `PerfModel`, so every thread reads the same warm
//!   [`StageCache`](super::StageCache); results are returned in
//!   strategy order and are bit-deterministic regardless of
//!   interleaving (cache entries are pure functions of their key).
//!
//! [`PlanOutcome`] carries the deduped candidates with their
//! [`PlanPerf`], aggregate [`SolveStats`], strategy provenance, and —
//! through [`PlanOutcome::frontier_flags`] and
//! [`PlanOutcome::recommend_idx`] — the Pareto frontier and the paper's
//! δ ≥ 0.8 recommendation rule, evaluated either on the deterministic
//! closed-form `(t_iter, c_iter)` or, when the request asks for
//! robustness, on the worst-case/mean scenario scores (the gap both
//! SMLT and MLLess flag for static serverless planners: a plan that is
//! optimal in the deterministic model can be fragile under cold starts
//! and stragglers).
//!
//! Robust and SLO re-scoring run through the
//! [`score`](crate::planner::score) work-queue: distinct plans are
//! collected under their canonical [`PlanKey`], and the `(plan, seed)`
//! replay grid fans out over the scoped worker pool with results
//! reduced in the serial order — reports stay byte-deterministic while
//! scoring saturates the machine (the "fast re-plan" requirement of
//! mid-run re-planning).

use std::collections::HashSet;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::Plan;
use crate::planner::optimizer::SolveStats;
use crate::planner::pareto::{pareto_flags, recommend_among};
use crate::planner::perf_model::{PerfModel, PlanPerf};
use crate::planner::score::{robust_scores, slo_scores, PlanKey, PlanSet};
use crate::planner::{bayes, miqp, optimizer, tpdmp};
use crate::platform::PlatformSpec;
use crate::serve::TrafficSpec;
use crate::simcore::ScenarioSpec;

/// How a robust request ranks candidates across its seeded replays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RobustRank {
    /// Worst-case scenario `(t, c)` over the seeds (the default).
    Worst,
    /// Mean scenario `(t, c)` over the seeds.
    Mean,
}

impl RobustRank {
    pub fn as_str(&self) -> &'static str {
        match self {
            RobustRank::Worst => "worst",
            RobustRank::Mean => "mean",
        }
    }

    pub fn parse(s: &str) -> Option<RobustRank> {
        match s {
            "worst" => Some(RobustRank::Worst),
            "mean" => Some(RobustRank::Mean),
            _ => None,
        }
    }
}

/// Scenario-robust selection spec: re-score every candidate plan under
/// `seeds` seeded replays of `scenario` (seeds `1..=seeds`, in order —
/// byte-deterministic) and rank by `rank` instead of the deterministic
/// point estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct RobustSpec {
    pub scenario: ScenarioSpec,
    pub seeds: usize,
    pub rank: RobustRank,
}

impl RobustSpec {
    pub const MAX_SEEDS: usize = 256;

    pub fn validate(&self) -> Result<()> {
        if self.scenario.is_deterministic() {
            bail!(
                "robust selection under the deterministic scenario is a \
                 no-op; pick a perturbing scenario ({})",
                ScenarioSpec::SYNTAX
            );
        }
        if self.seeds == 0 || self.seeds > Self::MAX_SEEDS {
            bail!(
                "robust seeds must be in 1..={} (got {})",
                Self::MAX_SEEDS,
                self.seeds
            );
        }
        Ok(())
    }
}

/// A candidate's scores across the robust spec's seeded replays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustScore {
    pub worst_t: f64,
    pub worst_c: f64,
    pub mean_t: f64,
    pub mean_c: f64,
}

/// SLO-aware serving selection spec (the serving-tier analogue of
/// [`RobustSpec`]): re-score every candidate plan under `seeds` seeded
/// serving replays of `traffic` (seeds `1..=seeds`, in order —
/// byte-deterministic) and rank by $/1k-requests subject to the p99
/// latency target.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    /// p99 end-to-end request latency target, milliseconds.
    pub p99_ms: f64,
    /// Arrival process each replay serves.
    pub traffic: TrafficSpec,
    pub seeds: usize,
}

/// Arrival horizon of each SLO scoring replay, seconds. Fixed (not a
/// knob) so two sessions score a candidate identically.
pub const SLO_REPLAY_DURATION_S: f64 = 10.0;

impl SloSpec {
    pub fn validate(&self) -> Result<()> {
        if !self.p99_ms.is_finite() || self.p99_ms <= 0.0 {
            bail!(
                "SLO p99 target must be a positive finite number of \
                 milliseconds, got {}",
                self.p99_ms
            );
        }
        if self.seeds == 0 || self.seeds > RobustSpec::MAX_SEEDS {
            bail!(
                "slo seeds must be in 1..={} (got {})",
                RobustSpec::MAX_SEEDS,
                self.seeds
            );
        }
        Ok(())
    }
}

/// A candidate's scores across the SLO spec's seeded serving replays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloScore {
    /// Worst replayed p99 latency across the seeds, milliseconds.
    pub p99_ms: f64,
    /// Mean $/1k-requests across the seeds.
    pub cost_per_1k_usd: f64,
    /// Whether the worst p99 meets the target (and every replay
    /// actually completed requests — an empty replay certifies
    /// nothing).
    pub feasible: bool,
}

/// What goes into a strategy: everything the §3.4 program needs beyond
/// the model/platform pair the [`PerfModel`] already carries.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRequest {
    /// Micro-batches per global batch (`B/b` in §3.4.1).
    pub n_micro_global: usize,
    /// Weight pairs (α1, α2) to sweep — the Pareto trace.
    pub weights: Vec<(f64, f64)>,
    /// Candidate data-parallel degrees (`D` in §3.4.1). One
    /// user-controlled space for EVERY strategy (historically each
    /// solver hardcoded its own copy).
    pub dp_options: Vec<usize>,
    /// Hard cap on search nodes per weight (anytime behaviour).
    pub node_budget: u64,
    /// Optional wall-clock budget for the whole sweep: a strategy stops
    /// starting new weight solves once it is exhausted (best-effort
    /// anytime behaviour; results then depend on machine speed, so
    /// leave it unset where byte-replayable output matters).
    pub time_budget_s: Option<f64>,
    /// Optional scenario-robust selection (see [`RobustSpec`]).
    pub robust: Option<RobustSpec>,
    /// Optional SLO-aware serving selection (see [`SloSpec`]).
    pub slo: Option<SloSpec>,
    /// Force the `bnb` strategy onto the single-threaded search
    /// (`--search serial`). The parallel search returns the
    /// byte-identical plan, but its [`SolveStats`] node counts are
    /// pruning-order-dependent — serial mode keeps them exact, and
    /// keeps a *binding* node budget's anytime truncation reproducible.
    pub serial_search: bool,
}

impl PlanRequest {
    pub fn new(n_micro_global: usize) -> Self {
        Self {
            n_micro_global,
            weights: super::DEFAULT_WEIGHTS.to_vec(),
            dp_options: super::DEFAULT_DP_OPTIONS.to_vec(),
            node_budget: optimizer::DEFAULT_NODE_BUDGET,
            time_budget_s: None,
            robust: None,
            slo: None,
            serial_search: false,
        }
    }

    /// Reject requests no strategy can act on sensibly: empty or
    /// non-finite weight sweeps, dp degrees of zero, a dp space that is
    /// not strictly increasing (duplicates would silently re-search),
    /// and dp degrees beyond the platform's concurrency cap — the
    /// platform cannot price (or launch) more concurrent replicas than
    /// it sells.
    pub fn validate(&self, platform: &PlatformSpec) -> Result<()> {
        if self.n_micro_global == 0 {
            bail!("n_micro_global must be >= 1");
        }
        if self.weights.is_empty() {
            bail!("the weight sweep must contain at least one (α1, α2) pair");
        }
        for &(a1, a2) in &self.weights {
            if !(a1.is_finite() && a2.is_finite() && a1 >= 0.0 && a2 >= 0.0) {
                bail!("weights must be finite and non-negative, got ({a1}, {a2})");
            }
        }
        validate_dp_options(&self.dp_options, platform)?;
        if self.node_budget == 0 {
            bail!("node_budget must be >= 1");
        }
        if let Some(t) = self.time_budget_s {
            if !(t.is_finite() && t > 0.0) {
                bail!("time_budget_s must be a positive finite number");
            }
        }
        if let Some(r) = &self.robust {
            r.validate()?;
        }
        if let Some(s) = &self.slo {
            s.validate()?;
        }
        Ok(())
    }

    fn deadline(&self) -> Option<Instant> {
        self.time_budget_s
            .map(|s| Instant::now() + Duration::from_secs_f64(s))
    }
}

fn expired(deadline: &Option<Instant>) -> bool {
    deadline.map(|d| Instant::now() >= d).unwrap_or(false)
}

/// THE dp-space invariant, shared by [`PlanRequest::validate`] and
/// `ExperimentConfig::validate` so the two layers can never drift:
/// non-empty, strictly increasing positive degrees, none beyond what
/// the platform will concurrently launch (and therefore price).
pub fn validate_dp_options(
    dp_options: &[usize],
    platform: &PlatformSpec,
) -> Result<()> {
    if dp_options.is_empty() {
        bail!("dp_options must contain at least one degree");
    }
    for w in dp_options.windows(2) {
        if w[0] >= w[1] {
            bail!(
                "dp_options must be strictly increasing (got {dp_options:?})"
            );
        }
    }
    for &d in dp_options {
        if d == 0 {
            bail!("dp_options entries must be >= 1");
        }
        if d > platform.max_concurrency {
            bail!(
                "dp degree {d} exceeds {}'s concurrency cap of {} \
                 functions — the platform cannot price that many \
                 concurrent replicas",
                platform.name,
                platform.max_concurrency
            );
        }
    }
    Ok(())
}

/// One evaluated configuration in an outcome.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    pub plan: Plan,
    /// Deterministic closed-form evaluation.
    pub perf: PlanPerf,
    /// The (α1, α2) pair whose solve first produced this plan.
    pub weights: (f64, f64),
    /// Scenario scores; present iff the request asked for robustness.
    pub robust: Option<RobustScore>,
    /// Serving-replay scores; present iff the request carried an SLO.
    pub slo: Option<SloScore>,
}

impl PlanCandidate {
    /// The `(t, c)` pair candidates are ranked by: the deterministic
    /// point estimate, or — under a robust request — the worst-case or
    /// mean scenario scores.
    pub fn metric(&self, rank: Option<RobustRank>) -> (f64, f64) {
        match (rank, &self.robust) {
            (Some(RobustRank::Worst), Some(r)) => (r.worst_t, r.worst_c),
            (Some(RobustRank::Mean), Some(r)) => (r.mean_t, r.mean_c),
            _ => (self.perf.t_iter, self.perf.c_iter),
        }
    }
}

/// What comes out of a strategy: deduped candidates (in weight order),
/// aggregate solve stats, strategy provenance, and the robust spec the
/// scores were produced under (if any).
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// Registry key of the strategy that produced this outcome.
    pub strategy: String,
    pub candidates: Vec<PlanCandidate>,
    /// Aggregated over the weight sweep. Diagnostics only: wall time is
    /// machine-dependent and the parallel `bnb` search's node/prune
    /// counts are pruning-order-dependent, so NOTHING in here may reach
    /// a rendered report (reports must byte-replay).
    pub stats: SolveStats,
    pub robust: Option<RobustSpec>,
    pub slo: Option<SloSpec>,
}

impl PlanOutcome {
    /// The active ranking lens (None = deterministic point estimate).
    pub fn rank(&self) -> Option<RobustRank> {
        self.robust.as_ref().map(|r| r.rank)
    }

    /// Each candidate's ranking metric, in candidate order.
    pub fn metrics(&self) -> Vec<(f64, f64)> {
        let rank = self.rank();
        self.candidates.iter().map(|c| c.metric(rank)).collect()
    }

    /// Per-candidate Pareto non-domination flags under the ranking
    /// metric.
    pub fn frontier_flags(&self) -> Vec<bool> {
        pareto_flags(&self.metrics())
    }

    /// The non-dominated candidates, in candidate order.
    pub fn frontier(&self) -> Vec<&PlanCandidate> {
        self.candidates
            .iter()
            .zip(self.frontier_flags())
            .filter(|(_, f)| *f)
            .map(|(c, _)| c)
            .collect()
    }

    /// The recommendation rule. Under an SLO request, candidates are
    /// ranked by the serving objective — cheapest $/1k-requests among
    /// the plans whose replayed worst-case p99 meets the target; if no
    /// candidate is feasible, the one closest to the target (lowest
    /// p99) so the caller sees *how* infeasible the request is.
    /// Otherwise: the paper's δ ≥ 0.8 rule over the frontier, under
    /// the (possibly robust) ranking metric: the fastest configuration
    /// whose efficiency `δ = (t_mc/t_p − 1) / (c_p/c_mc − 1)` stays
    /// ≥ 0.8 relative to the minimum-cost point. Returns the candidate
    /// index.
    pub fn recommend_idx(&self) -> Option<usize> {
        if self.slo.is_some() {
            return self.recommend_slo_idx();
        }
        let metrics = self.metrics();
        let front: Vec<usize> = self
            .frontier_flags()
            .iter()
            .enumerate()
            .filter(|(_, f)| **f)
            .map(|(i, _)| i)
            .collect();
        recommend_among(&metrics, &front)
    }

    /// The SLO serving objective over candidates carrying an
    /// [`SloScore`]. Ties break toward lower p99 then lower index, so
    /// the pick is deterministic.
    fn recommend_slo_idx(&self) -> Option<usize> {
        let scored: Vec<(usize, SloScore)> = self
            .candidates
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.slo.map(|s| (i, s)))
            .collect();
        let best_feasible = scored
            .iter()
            .filter(|(_, s)| s.feasible)
            .min_by(|(_, a), (_, b)| {
                a.cost_per_1k_usd
                    .partial_cmp(&b.cost_per_1k_usd)
                    .unwrap()
                    .then(a.p99_ms.partial_cmp(&b.p99_ms).unwrap())
            });
        if let Some(&(i, _)) = best_feasible {
            return Some(i);
        }
        scored
            .iter()
            .min_by(|(_, a), (_, b)| {
                a.p99_ms.partial_cmp(&b.p99_ms).unwrap()
            })
            .map(|&(i, _)| i)
    }

    pub fn recommended(&self) -> Option<&PlanCandidate> {
        self.recommend_idx().map(|i| &self.candidates[i])
    }
}

/// A co-optimization strategy: solve a [`PlanRequest`] against a
/// (possibly shared) [`PerfModel`]. Implementations must be pure
/// functions of `(perf's model/platform/sync/chunking, req)` — that is
/// what makes [`race`] deterministic and `--strategy all` output
/// byte-replayable.
pub trait Planner: Sync {
    /// Registry key (also the provenance string in plan artifacts).
    fn name(&self) -> &'static str;

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome;
}

/// Registry keys, in race/report order.
pub const STRATEGIES: [&str; 5] = ["bnb", "miqp", "bayes", "tpdmp", "sweep"];

/// Look up a strategy by registry key.
pub fn strategy_by_name(name: &str) -> Option<&'static dyn Planner> {
    static BNB: Bnb = Bnb;
    static MIQP: Miqp = Miqp;
    static BAYES: Bayes = Bayes;
    static TPDMP: TpdmpStrategy = TpdmpStrategy;
    static SWEEP: GridSweep = GridSweep;
    match name {
        "bnb" => Some(&BNB),
        "miqp" => Some(&MIQP),
        "bayes" => Some(&BAYES),
        "tpdmp" => Some(&TPDMP),
        "sweep" => Some(&SWEEP),
        _ => None,
    }
}

/// Solve `req` with the named strategy and, when the request carries a
/// [`RobustSpec`], re-score every candidate under the seeded scenario
/// lenses. This is the ONE entrypoint `Experiment::plan`, the CLI, the
/// figure generators and the benches go through.
pub fn solve_request(
    name: &str,
    perf: &PerfModel<'_>,
    req: &PlanRequest,
) -> Result<PlanOutcome> {
    let Some(planner) = strategy_by_name(name) else {
        bail!(
            "unknown plan strategy {name:?} (available: {})",
            STRATEGIES.join(" ")
        );
    };
    req.validate(perf.platform)?;
    let mut outcome = planner.solve(perf, req);
    if let Some(spec) = &req.robust {
        apply_robustness(&mut outcome, perf, spec);
    }
    if let Some(spec) = &req.slo {
        apply_slo(&mut outcome, perf, spec)?;
    }
    Ok(outcome)
}

/// Race several strategies in parallel threads over ONE shared
/// `PerfModel` (and therefore one shared warm `StageCache`). Outcomes
/// come back in `names` order; unknown names fail before any thread
/// spawns. Robust re-scoring happens once per DISTINCT plan after the
/// race (strategies routinely converge on the same optimum — the
/// agreement suite pins `bnb` == `miqp` — so per-thread scoring would
/// replay the same seeded simulations several times over).
pub fn race(
    perf: &PerfModel<'_>,
    req: &PlanRequest,
    names: &[&str],
) -> Result<Vec<PlanOutcome>> {
    for n in names {
        if strategy_by_name(n).is_none() {
            bail!(
                "unknown plan strategy {n:?} (available: {})",
                STRATEGIES.join(" ")
            );
        }
    }
    req.validate(perf.platform)?;
    // threads run the pure searches; scoring is hoisted past the barrier
    let search_req = PlanRequest { robust: None, slo: None, ..req.clone() };
    let mut outcomes: Vec<PlanOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = names
            .iter()
            .map(|&n| {
                let sr = &search_req;
                scope.spawn(move || solve_request(n, perf, sr))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .map_err(|_| anyhow::anyhow!("strategy thread panicked"))?
            })
            .collect::<Result<Vec<_>>>()
    })?;
    if let Some(spec) = &req.robust {
        let set = collect_distinct(&outcomes);
        let scores = robust_scores(perf, set.plans(), spec);
        for out in &mut outcomes {
            for cand in &mut out.candidates {
                let i = set.index_of(&cand.plan).expect("plan collected");
                cand.robust = Some(scores[i]);
            }
            out.robust = Some(spec.clone());
        }
    }
    if let Some(spec) = &req.slo {
        let set = collect_distinct(&outcomes);
        let scores = slo_scores(perf, set.plans(), spec)?;
        for out in &mut outcomes {
            for cand in &mut out.candidates {
                let i = set.index_of(&cand.plan).expect("plan collected");
                cand.slo = Some(scores[i]);
            }
            out.slo = Some(spec.clone());
        }
    }
    Ok(outcomes)
}

/// The distinct plans across several outcomes, in (strategy, candidate)
/// order — the deterministic job order of the scoring work-queue.
fn collect_distinct(outcomes: &[PlanOutcome]) -> PlanSet {
    let mut set = PlanSet::new();
    for out in outcomes {
        for cand in &out.candidates {
            set.insert(&cand.plan);
        }
    }
    set
}

/// Re-score every candidate of one outcome (the single-strategy path)
/// through the parallel scoring work-queue — seeds 1..=n, reduced in
/// order, the same engine and streams `simulate --scenario` uses, so a
/// robust pick is judged by exactly the noise the scenario lab replays.
fn apply_robustness(
    outcome: &mut PlanOutcome,
    perf: &PerfModel<'_>,
    spec: &RobustSpec,
) {
    let mut set = PlanSet::new();
    for cand in &outcome.candidates {
        set.insert(&cand.plan);
    }
    let scores = robust_scores(perf, set.plans(), spec);
    for cand in &mut outcome.candidates {
        let i = set.index_of(&cand.plan).expect("plan collected");
        cand.robust = Some(scores[i]);
    }
    outcome.robust = Some(spec.clone());
}

/// Re-score every candidate of one outcome under the SLO spec's
/// serving replays (the single-strategy path) — seeds 1..=n through
/// the work-queue, the same `serve` engine and arrival streams the
/// `serve` subcommand replays, so an SLO pick is judged by exactly the
/// deployment it will run as.
fn apply_slo(
    outcome: &mut PlanOutcome,
    perf: &PerfModel<'_>,
    spec: &SloSpec,
) -> Result<()> {
    let mut set = PlanSet::new();
    for cand in &outcome.candidates {
        set.insert(&cand.plan);
    }
    let scores = slo_scores(perf, set.plans(), spec)?;
    for cand in &mut outcome.candidates {
        let i = set.index_of(&cand.plan).expect("plan collected");
        cand.slo = Some(scores[i]);
    }
    outcome.slo = Some(spec.clone());
    Ok(())
}

fn push_dedup(
    seen: &mut HashSet<PlanKey>,
    candidates: &mut Vec<PlanCandidate>,
    plan: Plan,
    perf: PlanPerf,
    weights: (f64, f64),
) {
    if seen.insert(PlanKey::of(&plan)) {
        candidates.push(PlanCandidate {
            plan,
            perf,
            weights,
            robust: None,
            slo: None,
        });
    }
}

fn outcome(
    name: &str,
    candidates: Vec<PlanCandidate>,
    mut stats: SolveStats,
    start: Instant,
) -> PlanOutcome {
    stats.solve_time_s = start.elapsed().as_secs_f64();
    PlanOutcome {
        strategy: name.to_string(),
        candidates,
        stats,
        robust: None,
        slo: None,
    }
}

// ---------------------------------------------------------------------------
// the five registry strategies
// ---------------------------------------------------------------------------

/// FuncPipe's exact branch-and-bound (`optimizer.rs`) — the default.
struct Bnb;

impl Planner for Bnb {
    fn name(&self) -> &'static str {
        "bnb"
    }

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome {
        let start = Instant::now();
        let deadline = req.deadline();
        let mut stats = SolveStats::default();
        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for &w in &req.weights {
            if expired(&deadline) {
                break;
            }
            // Parallel by default — byte-identical plans, faster; the
            // serial path keeps exact SolveStats (see PlanRequest).
            let solved = if req.serial_search {
                optimizer::solve_with(
                    perf,
                    &req.dp_options,
                    req.node_budget,
                    req.n_micro_global,
                    w,
                )
            } else {
                optimizer::solve_parallel(
                    perf,
                    &req.dp_options,
                    req.node_budget,
                    req.n_micro_global,
                    w,
                )
            };
            if let Some((plan, pf, s)) = solved {
                stats.nodes += s.nodes;
                stats.leaves += s.leaves;
                stats.pruned_bound += s.pruned_bound;
                stats.pruned_memory += s.pruned_memory;
                push_dedup(&mut seen, &mut candidates, plan, pf, w);
            }
        }
        outcome("bnb", candidates, stats, start)
    }
}

/// The direct binary-variable solver (`miqp.rs`) — exact, slower;
/// certifies `bnb`.
struct Miqp;

impl Planner for Miqp {
    fn name(&self) -> &'static str {
        "miqp"
    }

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome {
        let start = Instant::now();
        let deadline = req.deadline();
        let mut stats = SolveStats::default();
        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for &w in &req.weights {
            if expired(&deadline) {
                break;
            }
            if let Some(sol) = miqp::solve_with(
                perf,
                &req.dp_options,
                req.node_budget,
                req.n_micro_global,
                w,
            ) {
                stats.nodes += sol.nodes;
                stats.leaves += 1;
                let pf = perf.evaluate(&sol.plan);
                push_dedup(&mut seen, &mut candidates, sol.plan, pf, w);
            }
        }
        outcome("miqp", candidates, stats, start)
    }
}

/// The GP + expected-improvement baseline (`bayes.rs`), seeded and
/// therefore deterministic.
struct Bayes;

impl Planner for Bayes {
    fn name(&self) -> &'static str {
        "bayes"
    }

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome {
        let start = Instant::now();
        let deadline = req.deadline();
        let params = bayes::BayesParams::default();
        let mut stats = SolveStats::default();
        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for &w in &req.weights {
            if expired(&deadline) {
                break;
            }
            if let Some((plan, pf)) = bayes::solve_with(
                perf,
                &req.dp_options,
                &params,
                req.n_micro_global,
                w,
            ) {
                stats.nodes += params.total_rounds as u64;
                stats.leaves += params.total_rounds as u64;
                push_dedup(&mut seen, &mut candidates, plan, pf, w);
            }
        }
        outcome("bayes", candidates, stats, start)
    }
}

/// The §5.6 TPDMP baseline (`tpdmp.rs`): throughput-max partition under
/// a (d, uniform tier) grid.
struct TpdmpStrategy;

impl Planner for TpdmpStrategy {
    fn name(&self) -> &'static str {
        "tpdmp"
    }

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome {
        let start = Instant::now();
        let deadline = req.deadline();
        let mut stats = SolveStats::default();
        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for &w in &req.weights {
            if expired(&deadline) {
                break;
            }
            if let Some((plan, pf)) =
                tpdmp::solve_with(perf, &req.dp_options, req.n_micro_global, w)
            {
                stats.leaves += 1;
                push_dedup(&mut seen, &mut candidates, plan, pf, w);
            }
        }
        outcome("tpdmp", candidates, stats, start)
    }
}

/// Configuration-grid sweep: balanced contiguous partitions (1..=L
/// stages) × uniform memory tier × dp — the `pareto`-module sweeping
/// approach generalized from the weight grid to the configuration grid.
/// Cheap, memory-feasible by construction (validated), and a useful
/// sanity floor for the exact solvers.
struct GridSweep;

/// Cut positions splitting `l` layers into `s` contiguous groups whose
/// sizes differ by at most one (first `l % s` groups get the extra).
/// `pub(crate)` so the parallel B&B's greedy incumbent reuses it.
pub(crate) fn balanced_cuts(l: usize, s: usize) -> Vec<usize> {
    let base = l / s;
    let rem = l % s;
    let mut cuts = Vec::with_capacity(s - 1);
    let mut next = 0usize;
    for g in 0..s - 1 {
        next += base + usize::from(g < rem);
        cuts.push(next - 1);
    }
    cuts
}

impl Planner for GridSweep {
    fn name(&self) -> &'static str {
        "sweep"
    }

    fn solve(&self, perf: &PerfModel<'_>, req: &PlanRequest) -> PlanOutcome {
        let start = Instant::now();
        let deadline = req.deadline();
        let m = perf.model;
        let p = perf.platform;
        let l = m.n_layers();
        let mut stats = SolveStats::default();

        // evaluate the grid once; every weight then picks from it
        let mut grid: Vec<(Plan, PlanPerf)> = Vec::new();
        'grid: for &d in &req.dp_options {
            if d == 0 || req.n_micro_global % d != 0 {
                continue;
            }
            for s in 1..=l {
                if expired(&deadline) {
                    break 'grid;
                }
                let cuts = balanced_cuts(l, s);
                for tier in 0..p.n_tiers() {
                    stats.nodes += 1;
                    let plan = Plan {
                        cuts: cuts.clone(),
                        dp: d,
                        stage_tiers: vec![tier; s],
                        n_micro_global: req.n_micro_global,
                    };
                    if plan.validate(m, p).is_err() {
                        stats.pruned_memory += 1;
                        continue;
                    }
                    stats.leaves += 1;
                    let pf = perf.evaluate(&plan);
                    grid.push((plan, pf));
                }
            }
        }

        let mut seen = HashSet::new();
        let mut candidates = Vec::new();
        for &w in &req.weights {
            let best = grid.iter().min_by(|(_, a), (_, b)| {
                let ja = w.0 * a.c_iter + w.1 * a.t_iter;
                let jb = w.0 * b.c_iter + w.1 * b.t_iter;
                ja.partial_cmp(&jb).unwrap()
            });
            if let Some((plan, pf)) = best {
                push_dedup(
                    &mut seen,
                    &mut candidates,
                    plan.clone(),
                    pf.clone(),
                    w,
                );
            }
        }
        outcome("sweep", candidates, stats, start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};

    fn fixture() -> (crate::model::ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::resnet101(&p), 4, MergeCriterion::Compute);
        (m, p)
    }

    #[test]
    fn registry_knows_exactly_the_five_strategies() {
        for name in STRATEGIES {
            let s = strategy_by_name(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(strategy_by_name("gurobi").is_none());
        assert!(strategy_by_name("all").is_none(), "all is CLI sugar, not a strategy");
    }

    #[test]
    fn balanced_cuts_cover_the_layer_range() {
        assert_eq!(balanced_cuts(8, 1), Vec::<usize>::new());
        assert_eq!(balanced_cuts(8, 2), vec![3]);
        assert_eq!(balanced_cuts(8, 3), vec![2, 5]);
        assert_eq!(balanced_cuts(5, 5), vec![0, 1, 2, 3]);
        // s-1 cuts, strictly increasing, all < l-1
        for l in 1..=12usize {
            for s in 1..=l {
                let cuts = balanced_cuts(l, s);
                assert_eq!(cuts.len(), s - 1, "l={l} s={s}");
                assert!(cuts.windows(2).all(|w| w[0] < w[1]));
                assert!(cuts.iter().all(|&c| c < l - 1), "l={l} s={s}: {cuts:?}");
            }
        }
    }

    #[test]
    fn request_validation_rejects_bad_dp_spaces() {
        let p = PlatformSpec::aws_lambda();
        let ok = PlanRequest::new(16);
        ok.validate(&p).unwrap();

        let mut bad = PlanRequest::new(16);
        bad.dp_options = vec![];
        assert!(bad.validate(&p).is_err());
        bad.dp_options = vec![0, 2];
        assert!(bad.validate(&p).is_err());
        bad.dp_options = vec![4, 2];
        assert!(bad.validate(&p).is_err());
        bad.dp_options = vec![2, 2];
        assert!(bad.validate(&p).is_err());
        // beyond the platform's concurrency cap: unpriceable
        bad.dp_options = vec![p.max_concurrency + 1];
        assert!(bad.validate(&p).is_err());

        let mut bad = PlanRequest::new(16);
        bad.weights = vec![(1.0, f64::NAN)];
        assert!(bad.validate(&p).is_err());
        bad.weights = vec![];
        assert!(bad.validate(&p).is_err());

        let mut bad = PlanRequest::new(16);
        bad.robust = Some(RobustSpec {
            scenario: ScenarioSpec::deterministic(),
            seeds: 4,
            rank: RobustRank::Worst,
        });
        assert!(bad.validate(&p).is_err());
        let mut bad = PlanRequest::new(16);
        bad.robust = Some(RobustSpec {
            scenario: ScenarioSpec::parse("straggler").unwrap(),
            seeds: 0,
            rank: RobustRank::Worst,
        });
        assert!(bad.validate(&p).is_err());
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let req = PlanRequest::new(16);
        assert!(solve_request("chaos", &perf, &req).is_err());
        assert!(race(&perf, &req, &["bnb", "chaos"]).is_err());
    }

    #[test]
    fn every_strategy_solves_and_recommends() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2, 4];
        for name in STRATEGIES {
            let out = solve_request(name, &perf, &req).unwrap();
            assert_eq!(out.strategy, name);
            assert!(!out.candidates.is_empty(), "{name}: no candidates");
            for c in &out.candidates {
                c.plan.validate(&m, &p).unwrap();
                assert!(c.perf.t_iter.is_finite() && c.perf.t_iter > 0.0);
                assert!(req.dp_options.contains(&c.plan.dp), "{name}");
            }
            let flags = out.frontier_flags();
            assert_eq!(flags.len(), out.candidates.len());
            assert!(flags.iter().any(|f| *f), "{name}: empty frontier");
            let rec = out.recommend_idx().expect("recommendation");
            assert!(flags[rec], "{name}: recommendation off the frontier");
        }
    }

    #[test]
    fn race_returns_outcomes_in_strategy_order_deterministically() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2];
        let a = race(&perf, &req, &STRATEGIES).unwrap();
        let b = race(&perf, &req, &STRATEGIES).unwrap();
        assert_eq!(a.len(), STRATEGIES.len());
        for (i, name) in STRATEGIES.iter().enumerate() {
            assert_eq!(a[i].strategy, *name);
            assert_eq!(a[i].candidates.len(), b[i].candidates.len());
            // (node counts deliberately NOT compared: the parallel bnb
            // search's stats are pruning-order-dependent — only plans
            // and perf are byte-replay-pinned)
            for (ca, cb) in a[i].candidates.iter().zip(&b[i].candidates) {
                assert_eq!(ca.plan, cb.plan, "{name}");
                assert_eq!(
                    ca.perf.t_iter.to_bits(),
                    cb.perf.t_iter.to_bits(),
                    "{name}"
                );
            }
        }
    }

    #[test]
    fn robust_scores_replay_and_rank() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2];
        req.robust = Some(RobustSpec {
            scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
            seeds: 4,
            rank: RobustRank::Worst,
        });
        let a = solve_request("bnb", &perf, &req).unwrap();
        let b = solve_request("bnb", &perf, &req).unwrap();
        for (ca, cb) in a.candidates.iter().zip(&b.candidates) {
            let (ra, rb) = (ca.robust.unwrap(), cb.robust.unwrap());
            assert_eq!(ra.worst_t.to_bits(), rb.worst_t.to_bits());
            assert_eq!(ra.mean_c.to_bits(), rb.mean_c.to_bits());
            // the mean never exceeds the worst case, and scores are real
            assert!(ra.worst_t.is_finite() && ra.worst_t > 0.0);
            assert!(ra.mean_t <= ra.worst_t + 1e-12);
            assert!(ra.mean_c <= ra.worst_c + 1e-12);
            // the robust metric is what ranking sees
            assert_eq!(ca.metric(Some(RobustRank::Worst)), (ra.worst_t, ra.worst_c));
            assert_eq!(ca.metric(None), (ca.perf.t_iter, ca.perf.c_iter));
        }
        assert!(a.recommend_idx().is_some());
        assert_eq!(a.rank(), Some(RobustRank::Worst));
    }

    #[test]
    fn slo_validation_rejects_bad_specs() {
        let p = PlatformSpec::aws_lambda();
        let traffic = TrafficSpec::parse("poisson:600").unwrap();
        let mut req = PlanRequest::new(16);
        req.slo = Some(SloSpec {
            p99_ms: 0.0,
            traffic: traffic.clone(),
            seeds: 2,
        });
        assert!(req.validate(&p).is_err());
        req.slo = Some(SloSpec {
            p99_ms: f64::NAN,
            traffic: traffic.clone(),
            seeds: 2,
        });
        assert!(req.validate(&p).is_err());
        req.slo = Some(SloSpec { p99_ms: 100.0, traffic: traffic.clone(), seeds: 0 });
        assert!(req.validate(&p).is_err());
        req.slo = Some(SloSpec {
            p99_ms: 100.0,
            traffic: traffic.clone(),
            seeds: RobustSpec::MAX_SEEDS + 1,
        });
        assert!(req.validate(&p).is_err());
        req.slo = Some(SloSpec { p99_ms: 100.0, traffic, seeds: 2 });
        req.validate(&p).unwrap();
    }

    #[test]
    fn slo_scores_replay_and_pick_the_cheapest_feasible_plan() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2];
        req.slo = Some(SloSpec {
            // Generous target: with feasible candidates present, the
            // recommendation must meet it (the acceptance criterion).
            p99_ms: 120_000.0,
            traffic: TrafficSpec::parse("poisson:300").unwrap(),
            seeds: 2,
        });
        let a = solve_request("bnb", &perf, &req).unwrap();
        let b = solve_request("bnb", &perf, &req).unwrap();
        for (ca, cb) in a.candidates.iter().zip(&b.candidates) {
            let (sa, sb) = (ca.slo.unwrap(), cb.slo.unwrap());
            assert_eq!(sa.p99_ms.to_bits(), sb.p99_ms.to_bits());
            assert_eq!(
                sa.cost_per_1k_usd.to_bits(),
                sb.cost_per_1k_usd.to_bits()
            );
            assert!(sa.p99_ms.is_finite() && sa.p99_ms > 0.0);
            assert!(sa.cost_per_1k_usd > 0.0);
        }
        let rec = a.recommended().expect("slo recommendation");
        let rs = rec.slo.unwrap();
        assert!(
            rs.feasible && rs.p99_ms <= 120_000.0,
            "feasible candidates exist, so the pick must meet the SLO \
             (picked p99 {} ms)",
            rs.p99_ms
        );
        // ... and it is the cheapest feasible one
        for c in &a.candidates {
            let s = c.slo.unwrap();
            if s.feasible {
                assert!(rs.cost_per_1k_usd <= s.cost_per_1k_usd + 1e-12);
            }
        }

        // An impossible target still yields a deterministic pick — the
        // closest candidate, flagged infeasible.
        req.slo = Some(SloSpec {
            p99_ms: 0.001,
            traffic: TrafficSpec::parse("poisson:300").unwrap(),
            seeds: 2,
        });
        let tight = solve_request("bnb", &perf, &req).unwrap();
        let rec = tight.recommended().expect("infeasible still recommends");
        let rs = rec.slo.unwrap();
        assert!(!rs.feasible);
        for c in &tight.candidates {
            assert!(rs.p99_ms <= c.slo.unwrap().p99_ms + 1e-12);
        }
    }

    #[test]
    fn race_scores_slo_once_per_distinct_plan() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let mut req = PlanRequest::new(16);
        req.dp_options = vec![1, 2];
        req.slo = Some(SloSpec {
            p99_ms: 120_000.0,
            traffic: TrafficSpec::parse("poisson:300").unwrap(),
            seeds: 1,
        });
        let outs = race(&perf, &req, &["bnb", "miqp"]).unwrap();
        assert_eq!(outs.len(), 2);
        for out in &outs {
            assert_eq!(out.slo, req.slo);
            for c in &out.candidates {
                assert!(c.slo.is_some());
            }
        }
        // identical plans across strategies carry bit-identical scores
        for ca in &outs[0].candidates {
            for cb in &outs[1].candidates {
                if ca.plan == cb.plan {
                    let (sa, sb) = (ca.slo.unwrap(), cb.slo.unwrap());
                    assert_eq!(sa.p99_ms.to_bits(), sb.p99_ms.to_bits());
                }
            }
        }
    }

    #[test]
    fn time_budget_truncates_but_never_invents() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let full = solve_request("bnb", &perf, &PlanRequest::new(16)).unwrap();
        let mut req = PlanRequest::new(16);
        req.time_budget_s = Some(1e-9);
        let cut = solve_request("bnb", &perf, &req).unwrap();
        assert!(cut.candidates.len() <= full.candidates.len());
        for c in &cut.candidates {
            assert!(full.candidates.iter().any(|f| f.plan == c.plan));
        }
        let mut bad = PlanRequest::new(16);
        bad.time_budget_s = Some(0.0);
        assert!(bad.validate(&p).is_err());
    }
}
