//! TPDMP baseline (§5.6): the throughput-maximal graph-partition algorithm
//! of Tarnawski et al. assumes a *fixed* set of workers with fixed
//! resources; to use it in the serverless setting the paper grid-searches
//! the resource allocation and, for each allocation, asks TPDMP for the
//! partition that maximizes throughput (minimizes `t_iter`), then keeps
//! the grid point minimizing the objective (3).
//!
//! The gap to FuncPipe's co-optimizer is structural: TPDMP optimizes the
//! partition for *time only* and cannot trade a stage's tier against its
//! neighbours' — which is exactly what Fig. 9 demonstrates.

use crate::model::{ModelProfile, Plan};
use crate::planner::perf_model::{PerfModel, PlanPerf};
use crate::platform::PlatformSpec;

/// Grid-search wrapper around throughput-maximal partitioning — the
/// classic struct API over the shared [`solve_with`] core (the `tpdmp`
/// registry strategy calls the core directly against a shared
/// [`PerfModel`]).
pub struct Tpdmp<'a> {
    pub perf: PerfModel<'a>,
    pub dp_options: Vec<usize>,
}

impl<'a> Tpdmp<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        Self {
            perf: PerfModel::new(model, platform),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
        }
    }

    /// For a fixed (d, uniform tier): the partition minimizing `t_iter`.
    pub fn best_partition_fixed_resources(
        &self,
        d: usize,
        tier: usize,
        n_micro_global: usize,
    ) -> Option<(Plan, PlanPerf)> {
        best_partition_fixed(&self.perf, d, tier, n_micro_global)
    }

    /// Full TPDMP baseline: grid over (d, tier), throughput-max partition
    /// each, select by objective (3a).
    pub fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<(Plan, PlanPerf)> {
        solve_with(&self.perf, &self.dp_options, n_micro_global, alpha)
    }
}

/// For a fixed (d, uniform tier): the partition minimizing `t_iter`.
/// DFS with memory pruning (the tier is fixed so the space is just the
/// cut set; L ≤ 24 keeps this fast with bounding on committed time).
pub fn best_partition_fixed(
    perf: &PerfModel<'_>,
    d: usize,
    tier: usize,
    n_micro_global: usize,
) -> Option<(Plan, PlanPerf)> {
    let m = perf.model;
    let l = m.n_layers();
    if d == 0 || n_micro_global % d != 0 {
        return None;
    }
    let mu = n_micro_global / d;

    let mut best: Option<(f64, Plan)> = None;
    let mut cuts: Vec<usize> = Vec::new();
    // DFS over cut positions; evaluate complete cut sets.
    fn go(
        lo: usize,
        l: usize,
        cuts: &mut Vec<usize>,
        perf: &PerfModel<'_>,
        d: usize,
        tier: usize,
        mu: usize,
        n_micro_global: usize,
        best: &mut Option<(f64, Plan)>,
    ) {
        let m = perf.model;
        let p = perf.platform;
        for hi in lo..l {
            // stage [lo..=hi] feasibility on the fixed tier
            let act = m.range_act_bytes(lo, hi);
            let params = m.range_param_bytes(lo, hi);
            let copies = if d == 1 { 2 } else { 4 };
            let need = (mu as u64) * act
                + params * copies
                + p.base_mem_mb * 1024 * 1024;
            if need > p.tier(tier).mem_bytes() {
                // extending hi only grows memory: stop
                break;
            }
            if hi == l - 1 {
                let plan = Plan {
                    cuts: cuts.clone(),
                    dp: d,
                    stage_tiers: vec![tier; cuts.len() + 1],
                    n_micro_global,
                };
                let t = perf.evaluate(&plan).t_iter;
                if best.as_ref().map(|(b, _)| t < *b).unwrap_or(true) {
                    *best = Some((t, plan));
                }
            } else {
                cuts.push(hi);
                go(hi + 1, l, cuts, perf, d, tier, mu, n_micro_global, best);
                cuts.pop();
            }
        }
    }
    go(0, l, &mut cuts, perf, d, tier, mu, n_micro_global, &mut best);
    best.map(|(_, plan)| {
        let pf = perf.evaluate(&plan);
        (plan, pf)
    })
}

/// Full TPDMP baseline over any (possibly shared) [`PerfModel`]: grid
/// over (d, tier), throughput-max partition each, select by (3a).
pub fn solve_with(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(Plan, PlanPerf)> {
    let p = perf.platform;
    let mut best: Option<(f64, Plan, PlanPerf)> = None;
    for &d in dp_options {
        if d == 0 || n_micro_global % d != 0 {
            continue;
        }
        for tier in 0..p.n_tiers() {
            if let Some((plan, pf)) =
                best_partition_fixed(perf, d, tier, n_micro_global)
            {
                let j = alpha.0 * pf.c_iter + alpha.1 * pf.t_iter;
                if best.as_ref().map(|(b, _, _)| j < *b).unwrap_or(true) {
                    best = Some((j, plan, pf));
                }
            }
        }
    }
    best.map(|(_, plan, pf)| (plan, pf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};
    use crate::planner::optimizer::CoOptimizer;

    #[test]
    fn produces_feasible_plans() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::amoebanet_d18(&p), 6, MergeCriterion::Compute);
        let t = Tpdmp::new(&m, &p);
        let (plan, perf) = t.solve(16, (1.0, 2e-4)).unwrap();
        plan.validate(&m, &p).unwrap();
        assert!(perf.t_iter > 0.0);
        // uniform tier by construction
        assert!(plan.stage_tiers.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn co_optimizer_never_worse_than_tpdmp() {
        // FuncPipe's search space strictly contains TPDMP's, so for equal
        // objectives J(co-opt) <= J(TPDMP) — Fig. 9's premise.
        let p = PlatformSpec::aws_lambda();
        for name in ["amoebanet-d18", "bert-large"] {
            let m = merge_layers(
                &zoo::by_name(name, &p).unwrap(),
                6,
                MergeCriterion::Compute,
            );
            let alpha = (1.0, 2e-4);
            let (_, tp) = Tpdmp::new(&m, &p).solve(16, alpha).unwrap();
            let (_, co, _) =
                CoOptimizer::new(&m, &p).solve(16, alpha).unwrap();
            let j_t = alpha.0 * tp.c_iter + alpha.1 * tp.t_iter;
            let j_c = alpha.0 * co.c_iter + alpha.1 * co.t_iter;
            assert!(j_c <= j_t + 1e-12, "{name}: {j_c} > {j_t}");
        }
    }
}
