//! Bayesian-optimization baseline (§5.1/§5.6): CherryPick-style black-box
//! search over the joint (partition, d, tiers) space.
//!
//! Gaussian process surrogate (RBF kernel, Cholesky solve — implemented
//! here since no linear-algebra crate is available offline) + expected
//! improvement acquisition, optimized by candidate sampling. As in the
//! paper, configurations are scored with the performance model rather than
//! live measurements, and infeasible decodes (OOM) receive a penalty —
//! which is exactly why Bayes over-provisions: feasible-but-expensive
//! regions look safe (§5.6's observed cost inefficiency).

use crate::model::{ModelProfile, Plan};
use crate::planner::perf_model::{PerfModel, PlanPerf};
use crate::platform::PlatformSpec;
use crate::util::rng::Rng;

/// The GP search's hyper-parameters, separated from the model handle so
/// the `bayes` registry strategy can run the same search over a shared
/// [`PerfModel`].
#[derive(Debug, Clone)]
pub struct BayesParams {
    pub init_rounds: usize,
    pub total_rounds: usize,
    pub candidates_per_round: usize,
    pub seed: u64,
}

impl Default for BayesParams {
    fn default() -> Self {
        Self {
            init_rounds: 20,
            total_rounds: 100, // paper: 100 rounds
            candidates_per_round: 256,
            seed: 0xBA4E5,
        }
    }
}

/// The classic struct API over the shared [`solve_with`] core.
pub struct BayesOpt<'a> {
    pub perf: PerfModel<'a>,
    pub dp_options: Vec<usize>,
    pub init_rounds: usize,
    pub total_rounds: usize,
    pub candidates_per_round: usize,
    pub seed: u64,
}

impl<'a> BayesOpt<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        let d = BayesParams::default();
        Self {
            perf: PerfModel::new(model, platform),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
            init_rounds: d.init_rounds,
            total_rounds: d.total_rounds,
            candidates_per_round: d.candidates_per_round,
            seed: d.seed,
        }
    }

    /// Run the optimization; returns the best feasible plan found.
    pub fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<(Plan, PlanPerf)> {
        let params = BayesParams {
            init_rounds: self.init_rounds,
            total_rounds: self.total_rounds,
            candidates_per_round: self.candidates_per_round,
            seed: self.seed,
        };
        solve_with(&self.perf, &self.dp_options, &params, n_micro_global, alpha)
    }
}

/// Run the GP-EI search over any (possibly shared) [`PerfModel`];
/// returns the best feasible plan found (None if every round decoded to
/// OOM — the failure mode §5.1 reports). Deterministic in
/// `params.seed`.
pub fn solve_with(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    params: &BayesParams,
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(Plan, PlanPerf)> {
    Search { perf, dp_options, params }.solve(n_micro_global, alpha)
}

/// Borrowed search state shared by the struct API and the registry
/// strategy.
struct Search<'b, 'a> {
    perf: &'b PerfModel<'a>,
    dp_options: &'b [usize],
    params: &'b BayesParams,
}

impl Search<'_, '_> {
    fn dims(&self) -> usize {
        // [d] + [cut indicator per boundary] + [tier per layer]
        let l = self.perf.model.n_layers();
        1 + (l - 1) + l
    }

    /// Decode a point in [0,1]^dims into a Plan (may be invalid).
    fn decode(&self, x: &[f64], n_micro_global: usize) -> Plan {
        let l = self.perf.model.n_layers();
        let p = self.perf.platform;
        let di = ((x[0] * self.dp_options.len() as f64) as usize)
            .min(self.dp_options.len() - 1);
        let dp = self.dp_options[di];
        let cuts: Vec<usize> =
            (0..l - 1).filter(|&i| x[1 + i] >= 0.5).collect();
        // stage tier = tier channel of the stage's first layer
        let tier_of = |layer: usize| -> usize {
            ((x[l + layer] * p.n_tiers() as f64) as usize)
                .min(p.n_tiers() - 1)
        };
        let mut stage_tiers = Vec::with_capacity(cuts.len() + 1);
        let mut lo = 0usize;
        for &c in &cuts {
            stage_tiers.push(tier_of(lo));
            lo = c + 1;
        }
        stage_tiers.push(tier_of(lo));
        Plan { cuts, dp, stage_tiers, n_micro_global }
    }

    /// Objective with OOM penalty.
    fn score(&self, plan: &Plan, alpha: (f64, f64)) -> f64 {
        let m = self.perf.model;
        let p = self.perf.platform;
        if plan.validate(m, p).is_err() {
            return PENALTY;
        }
        let perf = self.perf.evaluate(plan);
        alpha.0 * perf.c_iter + alpha.1 * perf.t_iter
    }

    /// Run the optimization; returns the best feasible plan found (None
    /// if every round decoded to OOM — the failure mode §5.1 reports).
    fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<(Plan, PlanPerf)> {
        let mut rng = Rng::new(self.params.seed);
        let dims = self.dims();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut best: Option<(f64, Plan)> = None;

        for round in 0..self.params.total_rounds {
            let x = if round < self.params.init_rounds || ys.is_empty() {
                (0..dims).map(|_| rng.next_f64()).collect::<Vec<f64>>()
            } else {
                self.propose(&xs, &ys, &mut rng)
            };
            let plan = self.decode(&x, n_micro_global);
            let y = self.score(&plan, alpha);
            if y < PENALTY
                && best.as_ref().map(|(b, _)| y < *b).unwrap_or(true)
            {
                best = Some((y, plan));
            }
            xs.push(x);
            ys.push(y.min(PENALTY));
        }
        best.map(|(_, plan)| {
            let perf = self.perf.evaluate(&plan);
            (plan, perf)
        })
    }

    /// GP-EI proposal: fit a GP on (xs, ys-normalized), sample candidates,
    /// return the candidate with maximum expected improvement.
    fn propose(&self, xs: &[Vec<f64>], ys: &[f64], rng: &mut Rng) -> Vec<f64> {
        let n = xs.len();
        let dims = self.dims();
        // normalize y
        let mean = ys.iter().sum::<f64>() / n as f64;
        let std = (ys.iter().map(|y| (y - mean).powi(2)).sum::<f64>()
            / n as f64)
            .sqrt()
            .max(1e-9);
        let yn: Vec<f64> = ys.iter().map(|y| (y - mean) / std).collect();
        let y_best = yn.iter().cloned().fold(f64::INFINITY, f64::min);

        // kernel matrix with jitter
        let ell = 0.35 * (dims as f64).sqrt();
        let k = |a: &[f64], b: &[f64]| -> f64 {
            let d2: f64 =
                a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
            (-d2 / (2.0 * ell * ell)).exp()
        };
        let mut kmat = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                kmat[i * n + j] =
                    k(&xs[i], &xs[j]) + if i == j { 1e-6 } else { 0.0 };
            }
        }
        let chol = cholesky(&kmat, n);
        let alpha_vec = chol_solve(&chol, n, &yn);

        let mut best_x: Option<Vec<f64>> = None;
        let mut best_ei = f64::NEG_INFINITY;
        for _ in 0..self.params.candidates_per_round {
            let cand: Vec<f64> =
                (0..dims).map(|_| rng.next_f64()).collect();
            let kv: Vec<f64> = xs.iter().map(|x| k(x, &cand)).collect();
            let mu: f64 =
                kv.iter().zip(&alpha_vec).map(|(a, b)| a * b).sum();
            // predictive variance: k(x,x) - k_v^T K^-1 k_v
            let v = chol_forward(&chol, n, &kv);
            let var = (1.0 - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
            let sigma = var.sqrt();
            let z = (y_best - mu) / sigma;
            let ei = sigma * (z * norm_cdf(z) + norm_pdf(z));
            if ei > best_ei {
                best_ei = ei;
                best_x = Some(cand);
            }
        }
        best_x.unwrap_or_else(|| (0..dims).map(|_| rng.next_f64()).collect())
    }
}

const PENALTY: f64 = 1e6;

/// Lower-triangular Cholesky factor of an n×n SPD matrix (row-major);
/// the diagonal is clamped at 1e-12 so jittered kernel matrices never
/// produce NaNs.
fn cholesky(a: &[f64], n: usize) -> Vec<f64> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                l[i * n + j] = sum.max(1e-12).sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    l
}

/// Solve L L^T x = b.
fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let y = chol_forward(l, n, b);
    // back substitution with L^T
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// Forward substitution: solve L y = b.
fn chol_forward(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    y
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ(z) via Abramowitz–Stegun 7.1.26 erf approximation.
fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};

    #[test]
    fn erf_and_cdf_sane() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(norm_cdf(3.0) > 0.99);
        assert!(norm_cdf(-3.0) < 0.01);
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2);
        let x = chol_solve(&l, 2, &[8.0, 7.0]);
        // A x = b -> x = [1.25, 1.5]
        assert!((x[0] - 1.25).abs() < 1e-9, "{x:?}");
        assert!((x[1] - 1.5).abs() < 1e-9, "{x:?}");
    }

    #[test]
    fn finds_feasible_plan() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::amoebanet_d18(&p), 6, MergeCriterion::Compute);
        let b = BayesOpt::new(&m, &p);
        let (plan, perf) = b.solve(16, (1.0, 2e-4)).unwrap();
        plan.validate(&m, &p).unwrap();
        assert!(perf.t_iter.is_finite());
    }
}
