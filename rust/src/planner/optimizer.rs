//! FuncPipe's co-optimizer: exact branch-and-bound over the joint space of
//! partition boundaries × data-parallel degree × per-stage memory tiers,
//! minimizing the weighted objective (3a) under the memory constraints
//! (3b). Solves the same program as the paper's MIQP (§3.4/App. C) — see
//! DESIGN.md §7 for why B&B replaces Gurobi here — and is certified
//! against the direct binary-variable solver in [`miqp`](super::miqp).
//!
//! Search structure: for each admissible `d`, stages are built left to
//! right by DFS; each node fixes one more stage (its end layer + tier).
//! Pruning:
//!  * **feasibility** — constraint (3b) per stage;
//!  * **bound** — an admissible lower bound on the objective of any
//!    completion: committed compute/memory + remaining layers at their
//!    per-layer fastest tier and cheapest memory (`J_lb ≤ J` because
//!    `t_iter ≥ t_f + t_b^1 ≥ Σ(fwd+bwd)` and β, comm, (μ−1) lags ≥ 0).

use std::time::Instant;

use crate::model::{ModelProfile, Plan};
use crate::planner::perf_model::{PerfModel, PlanPerf};
use crate::platform::PlatformSpec;

/// Solver telemetry (§5.6 reports solution times; we report node counts
/// too).
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    pub nodes: u64,
    pub pruned_bound: u64,
    pub pruned_memory: u64,
    pub leaves: u64,
    pub solve_time_s: f64,
}

/// Default DFS node cap (anytime behaviour; never hit in practice for
/// merged models, L ≤ 24). Shared with
/// [`PlanRequest`](super::strategy::PlanRequest).
pub const DEFAULT_NODE_BUDGET: u64 = 50_000_000;

/// The co-optimizer — the classic struct API over the shared
/// [`solve_with`] core (the `bnb` registry strategy calls the core
/// directly against a shared [`PerfModel`]).
pub struct CoOptimizer<'a> {
    pub perf: PerfModel<'a>,
    /// Candidate data-parallel degrees (`D` in §3.4.1).
    pub dp_options: Vec<usize>,
    /// Hard cap on DFS nodes.
    pub node_budget: u64,
}

impl<'a> CoOptimizer<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        Self {
            perf: PerfModel::new(model, platform),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
            node_budget: DEFAULT_NODE_BUDGET,
        }
    }

    /// Minimize `alpha.0·c_iter + alpha.1·t_iter` for a global batch of
    /// `n_micro_global` micro-batches. Returns the best feasible plan.
    pub fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<(Plan, PlanPerf, SolveStats)> {
        solve_with(
            &self.perf,
            &self.dp_options,
            self.node_budget,
            n_micro_global,
            alpha,
        )
    }

    /// Convenience: solve for every weight pair; returns deduped plans.
    pub fn solve_weights(
        &self,
        n_micro_global: usize,
        weights: &[(f64, f64)],
    ) -> Vec<(Plan, PlanPerf)> {
        let mut out: Vec<(Plan, PlanPerf)> = Vec::new();
        for &w in weights {
            if let Some((plan, perf, _)) = self.solve(n_micro_global, w) {
                if !out.iter().any(|(p, _)| *p == plan) {
                    out.push((plan, perf));
                }
            }
        }
        out
    }
}

/// The branch-and-bound core, independent of the struct wrapper: solves
/// against any (possibly shared) [`PerfModel`], which is what lets
/// `plan --strategy all` race it in a thread against the other registry
/// strategies over one warm [`StageCache`](super::StageCache).
pub fn solve_with(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    node_budget: u64,
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(Plan, PlanPerf, SolveStats)> {
    let start = Instant::now();
    let mut stats = SolveStats::default();
    let mut best: Option<(f64, Plan)> = None;

    let m = perf.model;
    let p = perf.platform;
    let l = m.n_layers();

    // per-layer minimum compute (fastest tier) for the bound
    let fastest_tier = (0..p.n_tiers())
        .max_by(|&a, &b| {
            p.tier(a)
                .compute_speed
                .partial_cmp(&p.tier(b).compute_speed)
                .unwrap()
        })
        .unwrap();
    let min_layer_s: Vec<f64> = (0..l)
        .map(|i| m.layers[i].fwd_s[fastest_tier] + m.layers[i].bwd_s[fastest_tier])
        .collect();
    // suffix sums of the per-layer minima
    let mut suffix_min_s = vec![0.0; l + 1];
    for i in (0..l).rev() {
        suffix_min_s[i] = suffix_min_s[i + 1] + min_layer_s[i];
    }
    // per-layer minimum fwd/bwd lag contributions (fastest tier) for
    // the (μ-1)·Δ part of the bound: every remaining layer ends up in
    // some stage, so Δ_f ≥ its fwd time (suffix max).
    let mut suffix_max_fwd = vec![0.0f64; l + 1];
    let mut suffix_max_bwd = vec![0.0f64; l + 1];
    for i in (0..l).rev() {
        suffix_max_fwd[i] =
            suffix_max_fwd[i + 1].max(m.layers[i].fwd_s[fastest_tier]);
        suffix_max_bwd[i] =
            suffix_max_bwd[i + 1].max(m.layers[i].bwd_s[fastest_tier]);
    }

    for &d in dp_options {
        if d == 0 || n_micro_global % d != 0 {
            continue;
        }
        let mu = n_micro_global / d;
        if mu == 0 {
            continue;
        }
        // per-layer minimal feasible tier memory (GB) given (μ, d):
        // some stage must hold layer i, and that stage needs at least
        // the memory layer i alone requires — suffix max is a valid
        // bound on the remaining layers' largest stage allocation.
        let copies = if d == 1 { 2u64 } else { 4u64 };
        let mut suffix_min_gb = vec![0.0f64; l + 1];
        let mut infeasible_d = false;
        for i in (0..l).rev() {
            let need = (mu as u64) * m.layers[i].act_bytes
                + copies * m.layers[i].param_bytes
                + p.base_mem_mb * 1024 * 1024;
            let tier_gb = p
                .tiers
                .iter()
                .filter(|t| t.mem_bytes() >= need)
                .map(|t| t.mem_gb())
                .fold(f64::INFINITY, f64::min);
            if !tier_gb.is_finite() {
                infeasible_d = true; // a single layer cannot fit: skip d
                break;
            }
            suffix_min_gb[i] = suffix_min_gb[i + 1].max(tier_gb);
        }
        if infeasible_d {
            continue;
        }
        let mut ctx = Dfs {
            perf,
            node_budget,
            d,
            mu,
            n_micro_global,
            alpha,
            suffix_min_s: &suffix_min_s,
            suffix_max_fwd: &suffix_max_fwd,
            suffix_max_bwd: &suffix_max_bwd,
            suffix_min_gb: &suffix_min_gb,
            cuts: Vec::new(),
            tiers: Vec::new(),
            committed_s: 0.0,
            committed_gb: 0.0,
            max_fc: 0.0,
            max_bc: 0.0,
            committed_comm: 0.0,
            sync_lb: 0.0,
            stats: &mut stats,
            best: &mut best,
        };
        ctx.go(0);
    }

    stats.solve_time_s = start.elapsed().as_secs_f64();
    best.map(|(_, plan)| {
        let perf = perf.evaluate(&plan);
        (plan, perf, stats)
    })
}

struct Dfs<'b, 'a> {
    perf: &'b PerfModel<'a>,
    node_budget: u64,
    d: usize,
    mu: usize,
    n_micro_global: usize,
    alpha: (f64, f64),
    suffix_min_s: &'b [f64],
    suffix_max_fwd: &'b [f64],
    suffix_max_bwd: &'b [f64],
    suffix_min_gb: &'b [f64],
    cuts: Vec<usize>,
    tiers: Vec<usize>,
    committed_s: f64,
    committed_gb: f64,
    /// max committed per-stage fwd/bwd compute (for the (μ-1)·Δ bound)
    max_fc: f64,
    max_bc: f64,
    /// Σ over committed boundaries of their minimum transfer time
    committed_comm: f64,
    /// max over committed stages of their minimum sync time (d > 1)
    sync_lb: f64,
    stats: &'b mut SolveStats,
    best: &'b mut Option<(f64, Plan)>,
}

impl Dfs<'_, '_> {
    /// Extend the partial plan whose next unassigned layer is `lo`.
    fn go(&mut self, lo: usize) {
        let m = self.perf.model;
        let p = self.perf.platform;
        let l = m.n_layers();
        self.stats.nodes += 1;
        if self.stats.nodes > self.node_budget {
            return;
        }

        if lo == l {
            // complete plan: exact evaluation
            self.stats.leaves += 1;
            let plan = Plan {
                cuts: self.cuts.clone(),
                dp: self.d,
                stage_tiers: self.tiers.clone(),
                n_micro_global: self.n_micro_global,
            };
            debug_assert!(plan.validate(m, p).is_ok());
            let (t_iter, c_iter) = self.perf.quick(&plan);
            let j = self.alpha.0 * c_iter + self.alpha.1 * t_iter;
            if self.best.as_ref().map(|(b, _)| j < *b).unwrap_or(true) {
                *self.best = Some((j, plan));
            }
            return;
        }

        // bound: committed + optimistic remainder.
        // t_iter ≥ t_f + max_s t_b^s ≥ Σ(fc+bc) + (μ-1)(Δ_f + Δ_b), and
        // Δ_f ≥ max(max committed stage fwd, any remaining layer's
        // fastest-tier fwd) (likewise backward).
        if let Some((jbest, _)) = self.best.as_ref() {
            let delta_f = self.max_fc.max(self.suffix_max_fwd[lo]);
            let delta_b = self.max_bc.max(self.suffix_max_bwd[lo]);
            // β applies to every completion that has communication: any
            // partial with a committed stage (plus remaining layers) has
            // >= 2 stages, and any d > 1 plan syncs — admissible either way
            let beta_lb = if self.d > 1 || !self.tiers.is_empty() {
                p.beta
            } else {
                1.0
            };
            // compute is β-scaled; committed boundary transfers and the
            // largest committed stage's sync add on top (both appear in
            // t_f / max_s(t_b+t_s) regardless of later choices)
            let t_lb = beta_lb
                * (self.committed_s
                    + self.suffix_min_s[lo]
                    + (self.mu as f64 - 1.0) * (delta_f + delta_b))
                + self.committed_comm
                + self.sync_lb;
            let gb_lb = self.committed_gb + self.suffix_min_gb[lo];
            let c_lb =
                p.price_per_gb_s * (self.d as f64) * gb_lb * t_lb;
            let j_lb = self.alpha.0 * c_lb + self.alpha.1 * t_lb;
            if j_lb >= *jbest {
                self.stats.pruned_bound += 1;
                return;
            }
        }

        // branch: this stage covers [lo..=hi] on tier j. Try larger tiers
        // first (good incumbents early: feasible + fast). The per-stage
        // terms come from the PerfModel's StageCache, so revisiting a
        // (range, tier) pair anywhere in the search is O(1).
        for hi in lo..l {
            for j in (0..p.n_tiers()).rev() {
                let terms = self.perf.stage_terms(lo, hi, j);
                // feasibility (3b)
                let sync_copies = if self.d == 1 { 2 } else { 4 };
                let need = (self.mu as u64) * terms.act_bytes
                    + terms.param_bytes * sync_copies
                    + p.base_mem_mb * 1024 * 1024;
                if need > p.tier(j).mem_bytes() {
                    self.stats.pruned_memory += 1;
                    continue; // smaller tiers will also fail
                }
                let stage_fwd = terms.fwd_s;
                let stage_bwd = terms.bwd_s;
                let stage_gb = p.tier(j).mem_gb();
                let (old_fc, old_bc) = (self.max_fc, self.max_bc);
                let (old_comm, old_sync) = (self.committed_comm, self.sync_lb);

                // admissible comm contribution of the boundary after `hi`
                // (raw best-tier bandwidth ≥ any effective bandwidth)
                let w_best = p
                    .tiers
                    .iter()
                    .map(|t| t.bandwidth_bps)
                    .fold(0.0f64, f64::max);
                if hi < l - 1 {
                    let o = m.layers[hi].out_bytes as f64;
                    let g = m.layers[hi + 1].grad_bytes as f64;
                    self.committed_comm += 2.0 * (o + g) / w_best
                        + 4.0 * p.storage.latency_s;
                    self.cuts.push(hi);
                }
                if self.d > 1 {
                    // t_iter ≥ ... + t_s of this stage; its tier is known,
                    // raw tier bandwidth ≥ effective → admissible
                    let sync = crate::collective::sync_time(
                        self.perf.sync_alg,
                        terms.param_bytes as f64,
                        self.d,
                        p.tier(j).bandwidth_bps,
                        p.storage.latency_s,
                    );
                    self.sync_lb = self.sync_lb.max(sync);
                }
                self.tiers.push(j);
                self.committed_s += stage_fwd + stage_bwd;
                self.committed_gb += stage_gb;
                self.max_fc = self.max_fc.max(stage_fwd);
                self.max_bc = self.max_bc.max(stage_bwd);

                self.go(hi + 1);

                self.max_fc = old_fc;
                self.max_bc = old_bc;
                self.committed_gb -= stage_gb;
                self.committed_s -= stage_fwd + stage_bwd;
                self.tiers.pop();
                self.sync_lb = old_sync;
                self.committed_comm = old_comm;
                if hi < l - 1 {
                    self.cuts.pop();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};

    #[test]
    fn finds_feasible_optimal_plan() {
        let p = PlatformSpec::aws_lambda();
        let m0 = zoo::amoebanet_d18(&p);
        let m = merge_layers(&m0, 6, MergeCriterion::Compute);
        let opt = CoOptimizer::new(&m, &p);
        let (plan, perf, stats) = opt.solve(16, (1.0, 2e-4)).unwrap();
        plan.validate(&m, &p).unwrap();
        assert!(perf.t_iter > 0.0);
        assert!(stats.leaves > 0);
        assert!(stats.solve_time_s < 60.0);
    }

    #[test]
    fn cost_only_weight_prefers_cheap_plans() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            6,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (_, cheap, _) = opt.solve(16, (1.0, 0.0)).unwrap();
        let (_, fast, _) = opt.solve(16, (0.0, 1.0)).unwrap();
        assert!(cheap.c_iter <= fast.c_iter + 1e-12);
        assert!(fast.t_iter <= cheap.t_iter + 1e-12);
    }

    #[test]
    fn beats_pure_data_parallelism_on_big_models() {
        // the headline claim: co-optimized pipeline beats the LambdaML
        // shape (max-memory pure DP) on large models
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::amoebanet_d36(&p),
            8,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (plan, perf, _) = opt.solve(16, (1.0, 2e-4)).unwrap();
        // compare with best feasible pure-DP plan at max tier
        let pm = PerfModel::new(&m, &p);
        let mut best_dp = f64::INFINITY;
        for d in [1usize, 2, 4, 8, 16] {
            if 16 % d != 0 {
                continue;
            }
            let cand = Plan {
                cuts: vec![],
                dp: d,
                stage_tiers: vec![p.max_tier()],
                n_micro_global: 16,
            };
            if cand.validate(&m, &p).is_ok() {
                best_dp = best_dp.min(pm.evaluate(&cand).t_iter);
            }
        }
        assert!(
            perf.t_iter < best_dp,
            "co-opt {} !< best pure dp {}",
            perf.t_iter,
            best_dp
        );
        assert!(plan.n_stages() > 1, "expected pipeline: {plan:?}");
    }

    #[test]
    fn exhaustive_small_case_agrees() {
        // brute force over ALL plans for a tiny model and check B&B
        // returns the same optimum
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            4,
            MergeCriterion::Compute,
        );
        let mut opt = CoOptimizer::new(&m, &p);
        opt.dp_options = vec![1, 2, 4];
        let alpha = (1.0, 1e-4);
        let (plan, perf, _) = opt.solve(8, alpha).unwrap();
        let j_bb = alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;

        let pm = PerfModel::new(&m, &p);
        let mut j_brute = f64::INFINITY;
        let l = m.n_layers();
        // enumerate all 2^(l-1) cut sets × tiers × d
        for mask in 0u32..(1 << (l - 1)) {
            let cuts: Vec<usize> =
                (0..l - 1).filter(|&i| mask & (1 << i) != 0).collect();
            let s = cuts.len() + 1;
            let mut tier_idx = vec![0usize; s];
            loop {
                for &d in &[1usize, 2, 4] {
                    if 8 % d != 0 {
                        continue;
                    }
                    let plan = Plan {
                        cuts: cuts.clone(),
                        dp: d,
                        stage_tiers: tier_idx.clone(),
                        n_micro_global: 8,
                    };
                    if plan.validate(&m, &p).is_ok() {
                        let perf = pm.evaluate(&plan);
                        let j =
                            alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;
                        if j < j_brute {
                            j_brute = j;
                        }
                    }
                }
                // increment tier_idx (odometer)
                let mut k = 0;
                loop {
                    tier_idx[k] += 1;
                    if tier_idx[k] < p.n_tiers() {
                        break;
                    }
                    tier_idx[k] = 0;
                    k += 1;
                    if k == s {
                        break;
                    }
                }
                if k == s {
                    break;
                }
            }
        }
        assert!(
            (j_bb - j_brute).abs() < 1e-9 * j_brute.max(1.0),
            "B&B {j_bb} vs brute {j_brute} (plan {plan:?})"
        );
    }

    #[test]
    fn stage_cache_is_hot_in_search() {
        // thousands of DFS nodes revisit the same few hundred
        // (range, tier) stages: the memoized terms must serve the bulk
        // of lookups (the planner_search bench reports the same number)
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            6,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        opt.solve(16, (1.0, 2e-4)).unwrap();
        let cache = opt.perf.cache();
        assert!(cache.hits() > cache.misses(), "{cache:?}");
        assert!(
            cache.hit_rate() > 0.5,
            "hit rate {:.2} too low",
            cache.hit_rate()
        );
    }

    #[test]
    fn respects_dp_divisibility() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            4,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (plan, _, _) = opt.solve(6, (1.0, 1e-4)).unwrap();
        assert!(6 % plan.dp == 0);
    }
}
