//! FuncPipe's co-optimizer: exact branch-and-bound over the joint space of
//! partition boundaries × data-parallel degree × per-stage memory tiers,
//! minimizing the weighted objective (3a) under the memory constraints
//! (3b). Solves the same program as the paper's MIQP (§3.4/App. C) — see
//! DESIGN.md §7 for why B&B replaces Gurobi here — and is certified
//! against the direct binary-variable solver in [`miqp`](super::miqp).
//!
//! Search structure: for each admissible `d`, stages are built left to
//! right by DFS; each node fixes one more stage (its end layer + tier).
//! Pruning:
//!  * **feasibility** — constraint (3b) per stage;
//!  * **bound** — an admissible lower bound on the objective of any
//!    completion: committed compute/memory + remaining layers at their
//!    per-layer fastest tier and cheapest memory (`J_lb ≤ J` because
//!    `t_iter ≥ t_f + t_b^1 ≥ Σ(fwd+bwd)` and β, comm, (μ−1) lags ≥ 0).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::model::{ModelProfile, Plan};
use crate::planner::perf_model::{PerfModel, PlanPerf};
use crate::platform::PlatformSpec;

/// Solver telemetry (§5.6 reports solution times; we report node counts
/// too).
///
/// **Determinism caveat:** under [`solve_parallel`] the node/prune/leaf
/// counts are *pruning-order-dependent* — work packets tighten each
/// other's bound through a shared atomic, so how much of the tree each
/// packet visits varies run to run. The recommended **plan** is still
/// byte-identical to [`solve_with`] (see DESIGN.md §14), but stats are
/// diagnostics only and MUST stay out of byte-compared report JSON.
/// The serial path keeps exact, reproducible counts.
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    pub nodes: u64,
    pub pruned_bound: u64,
    pub pruned_memory: u64,
    pub leaves: u64,
    pub solve_time_s: f64,
}

impl SolveStats {
    fn absorb(&mut self, o: &SolveStats) {
        self.nodes += o.nodes;
        self.pruned_bound += o.pruned_bound;
        self.pruned_memory += o.pruned_memory;
        self.leaves += o.leaves;
    }
}

/// Best-known feasible objective, shared across B&B work packets as
/// `f64` bits in an atomic. Only ever *tightened* (monotone min of
/// published leaf objectives, seeded with the greedy incumbent), so
/// every value it holds is the objective of some feasible plan —
/// pruning a node whose lower bound *strictly exceeds* it can never
/// discard an optimal completion, and a packet containing the serial
/// search's first optimum-achieving leaf always reaches that leaf
/// (its ancestors bound ≤ J* ≤ shared, so the strict test never
/// fires). See DESIGN.md §14 for the full admissibility argument.
struct SharedBound(AtomicU64);

impl SharedBound {
    fn new(v: f64) -> Self {
        Self(AtomicU64::new(v.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Monotone CAS-min: publish `v` iff it beats the current bound.
    fn tighten(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.0.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }
}

/// Default DFS node cap (anytime behaviour; never hit in practice for
/// merged models, L ≤ 24). Shared with
/// [`PlanRequest`](super::strategy::PlanRequest).
pub const DEFAULT_NODE_BUDGET: u64 = 50_000_000;

/// The co-optimizer — the classic struct API over the shared
/// [`solve_with`] core (the `bnb` registry strategy calls the core
/// directly against a shared [`PerfModel`]).
pub struct CoOptimizer<'a> {
    pub perf: PerfModel<'a>,
    /// Candidate data-parallel degrees (`D` in §3.4.1).
    pub dp_options: Vec<usize>,
    /// Hard cap on DFS nodes.
    pub node_budget: u64,
}

impl<'a> CoOptimizer<'a> {
    pub fn new(model: &'a ModelProfile, platform: &'a PlatformSpec) -> Self {
        Self {
            perf: PerfModel::new(model, platform),
            dp_options: crate::planner::DEFAULT_DP_OPTIONS.to_vec(),
            node_budget: DEFAULT_NODE_BUDGET,
        }
    }

    /// Minimize `alpha.0·c_iter + alpha.1·t_iter` for a global batch of
    /// `n_micro_global` micro-batches. Returns the best feasible plan.
    pub fn solve(
        &self,
        n_micro_global: usize,
        alpha: (f64, f64),
    ) -> Option<(Plan, PlanPerf, SolveStats)> {
        solve_with(
            &self.perf,
            &self.dp_options,
            self.node_budget,
            n_micro_global,
            alpha,
        )
    }

    /// Convenience: solve for every weight pair; returns deduped plans.
    pub fn solve_weights(
        &self,
        n_micro_global: usize,
        weights: &[(f64, f64)],
    ) -> Vec<(Plan, PlanPerf)> {
        let mut out: Vec<(Plan, PlanPerf)> = Vec::new();
        for &w in weights {
            if let Some((plan, perf, _)) = self.solve(n_micro_global, w) {
                if !out.iter().any(|(p, _)| *p == plan) {
                    out.push((plan, perf));
                }
            }
        }
        out
    }
}

/// The fastest-tier suffix arrays of the admissible bound, shared by
/// every `d` (and, in [`solve_parallel`], every work packet).
struct BoundPre {
    /// Suffix sums of per-layer minimum compute (fastest tier).
    suffix_min_s: Vec<f64>,
    /// Suffix maxes of per-layer fastest-tier fwd/bwd — the (μ−1)·Δ
    /// part of the bound: every remaining layer ends up in some stage,
    /// so Δ_f ≥ its fwd time (likewise backward).
    suffix_max_fwd: Vec<f64>,
    suffix_max_bwd: Vec<f64>,
}

impl BoundPre {
    fn build(m: &ModelProfile, p: &PlatformSpec) -> Self {
        let l = m.n_layers();
        // per-layer minimum compute (fastest tier) for the bound
        let fastest_tier = (0..p.n_tiers())
            .max_by(|&a, &b| {
                p.tier(a)
                    .compute_speed
                    .partial_cmp(&p.tier(b).compute_speed)
                    .unwrap()
            })
            .unwrap();
        let mut suffix_min_s = vec![0.0; l + 1];
        let mut suffix_max_fwd = vec![0.0f64; l + 1];
        let mut suffix_max_bwd = vec![0.0f64; l + 1];
        for i in (0..l).rev() {
            let fwd = m.layers[i].fwd_s[fastest_tier];
            let bwd = m.layers[i].bwd_s[fastest_tier];
            suffix_min_s[i] = suffix_min_s[i + 1] + fwd + bwd;
            suffix_max_fwd[i] = suffix_max_fwd[i + 1].max(fwd);
            suffix_max_bwd[i] = suffix_max_bwd[i + 1].max(bwd);
        }
        Self { suffix_min_s, suffix_max_fwd, suffix_max_bwd }
    }
}

/// Per-layer minimal feasible tier memory (GB) given `(μ, d)`, as a
/// suffix max: some stage must hold layer `i`, and that stage needs at
/// least the memory layer `i` alone requires. `None` when a single
/// layer cannot fit any tier (the whole `d` is infeasible).
fn suffix_min_gb_for(
    m: &ModelProfile,
    p: &PlatformSpec,
    mu: usize,
    d: usize,
) -> Option<Vec<f64>> {
    let l = m.n_layers();
    let copies = if d == 1 { 2u64 } else { 4u64 };
    let mut suffix_min_gb = vec![0.0f64; l + 1];
    for i in (0..l).rev() {
        let need = (mu as u64) * m.layers[i].act_bytes
            + copies * m.layers[i].param_bytes
            + p.base_mem_mb * 1024 * 1024;
        let tier_gb = p
            .tiers
            .iter()
            .filter(|t| t.mem_bytes() >= need)
            .map(|t| t.mem_gb())
            .fold(f64::INFINITY, f64::min);
        if !tier_gb.is_finite() {
            return None; // a single layer cannot fit: skip d
        }
        suffix_min_gb[i] = suffix_min_gb[i + 1].max(tier_gb);
    }
    Some(suffix_min_gb)
}

/// The admissible `d` values of a request, in `dp_options` order (the
/// serial traversal order), paired with their memory suffix bound.
fn admissible_dps(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    n_micro_global: usize,
) -> Vec<(usize, usize, Vec<f64>)> {
    let mut out = Vec::new();
    for &d in dp_options {
        if d == 0 || n_micro_global % d != 0 {
            continue;
        }
        let mu = n_micro_global / d;
        if mu == 0 {
            continue;
        }
        if let Some(gb) =
            suffix_min_gb_for(perf.model, perf.platform, mu, d)
        {
            out.push((d, mu, gb));
        }
    }
    out
}

/// The branch-and-bound core, independent of the struct wrapper: solves
/// against any (possibly shared) [`PerfModel`], which is what lets
/// `plan --strategy all` race it in a thread against the other registry
/// strategies over one warm [`StageCache`](super::StageCache). Strictly
/// serial with exact, reproducible [`SolveStats`]; [`solve_parallel`]
/// returns the byte-identical plan faster.
pub fn solve_with(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    node_budget: u64,
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(Plan, PlanPerf, SolveStats)> {
    let start = Instant::now();
    let mut stats = SolveStats::default();
    let mut best: Option<(f64, Plan)> = None;

    let pre = BoundPre::build(perf.model, perf.platform);
    for (d, mu, suffix_min_gb) in
        admissible_dps(perf, dp_options, n_micro_global)
    {
        let mut ctx = Dfs {
            perf,
            node_budget,
            d,
            mu,
            n_micro_global,
            alpha,
            suffix_min_s: &pre.suffix_min_s,
            suffix_max_fwd: &pre.suffix_max_fwd,
            suffix_max_bwd: &pre.suffix_max_bwd,
            suffix_min_gb: &suffix_min_gb,
            cuts: Vec::new(),
            tiers: Vec::new(),
            committed_s: 0.0,
            committed_gb: 0.0,
            max_fc: 0.0,
            max_bc: 0.0,
            committed_comm: 0.0,
            sync_lb: 0.0,
            stats: &mut stats,
            best: &mut best,
            shared: None,
        };
        ctx.go(0);
    }

    stats.solve_time_s = start.elapsed().as_secs_f64();
    best.map(|(_, plan)| {
        let perf = perf.evaluate(&plan);
        (plan, perf, stats)
    })
}

/// A greedy feasible incumbent to seed the shared bound: balanced
/// `s`-stage cuts at a uniform tier, over every admissible `(d, s,
/// tier)`. Cheap (O(L·tiers·|D|) evaluations through the stage cache)
/// and usually within a small factor of the optimum, so packets prune
/// from the first node instead of waiting for their own first leaf.
fn greedy_incumbent(
    perf: &PerfModel<'_>,
    dps: &[(usize, usize, Vec<f64>)],
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(f64, Plan)> {
    let m = perf.model;
    let p = perf.platform;
    let l = m.n_layers();
    let mut best: Option<(f64, Plan)> = None;
    for &(d, _mu, _) in dps {
        for s in 1..=l {
            let cuts = crate::planner::strategy::balanced_cuts(l, s);
            for tier in (0..p.n_tiers()).rev() {
                let plan = Plan {
                    cuts: cuts.clone(),
                    dp: d,
                    stage_tiers: vec![tier; s],
                    n_micro_global,
                };
                if plan.validate(m, p).is_err() {
                    continue;
                }
                let (t_iter, c_iter) = perf.quick(&plan);
                let j = alpha.0 * c_iter + alpha.1 * t_iter;
                if best.as_ref().map(|(b, _)| j < *b).unwrap_or(true) {
                    best = Some((j, plan));
                }
            }
        }
    }
    best
}

/// Work-sharing parallel branch-and-bound: the root frontier (first
/// stage boundary × dp × tier) is split into packets fanned over the
/// scoped worker pool, every packet prunes against the greedy-seeded
/// [`SharedBound`], and packet results merge in packet-enumeration
/// order with the serial tie-break (strict `<`), so the returned plan
/// is **byte-identical** to [`solve_with`] — only [`SolveStats`] are
/// pruning-order-dependent (see the type's caveat).
///
/// The node budget applies per packet: a *binding* budget truncates
/// each packet at a point that depends on how fast other packets
/// tightened the bound, making the anytime result timing-dependent
/// (like `time_budget_s` already is). The default budget never binds;
/// pass `serial_search` / use [`solve_with`] for exact anytime
/// semantics.
pub fn solve_parallel(
    perf: &PerfModel<'_>,
    dp_options: &[usize],
    node_budget: u64,
    n_micro_global: usize,
    alpha: (f64, f64),
) -> Option<(Plan, PlanPerf, SolveStats)> {
    let start = Instant::now();
    let m = perf.model;
    let p = perf.platform;
    let l = m.n_layers();
    let pre = BoundPre::build(m, p);
    let dps = admissible_dps(perf, dp_options, n_micro_global);
    let greedy = greedy_incumbent(perf, &dps, n_micro_global, alpha);
    let shared = SharedBound::new(
        greedy.as_ref().map(|(j, _)| *j).unwrap_or(f64::INFINITY),
    );

    // Packets in the serial traversal order: d in dp_options order,
    // then first-stage end ascending, then tier descending — the exact
    // nesting of `Dfs::go(0)`'s branch loop.
    let mut packets: Vec<(usize, usize, usize)> = Vec::new();
    for (di, _) in dps.iter().enumerate() {
        for hi0 in 0..l {
            for tier0 in (0..p.n_tiers()).rev() {
                packets.push((di, hi0, tier0));
            }
        }
    }

    let results: Vec<(SolveStats, Option<(f64, Plan)>)> =
        crate::planner::score::run_jobs(packets.len(), |pi| {
            let (di, hi0, tier0) = packets[pi];
            let (d, mu) = (dps[di].0, dps[di].1);
            let suffix_min_gb = &dps[di].2;
            let mut stats = SolveStats::default();
            let mut best: Option<(f64, Plan)> = None;
            // Replicate one iteration of the serial root branch loop:
            // commit stage [0..=hi0] on tier0, then DFS below it.
            stats.nodes += 1;
            let terms = perf.stage_terms(0, hi0, tier0);
            let sync_copies = if d == 1 { 2 } else { 4 };
            let need = (mu as u64) * terms.act_bytes
                + terms.param_bytes * sync_copies
                + p.base_mem_mb * 1024 * 1024;
            if need > p.tier(tier0).mem_bytes() {
                stats.pruned_memory += 1;
                return (stats, None);
            }
            let mut cuts = Vec::new();
            let mut committed_comm = 0.0;
            if hi0 < l - 1 {
                let w_best = p
                    .tiers
                    .iter()
                    .map(|t| t.bandwidth_bps)
                    .fold(0.0f64, f64::max);
                let o = m.layers[hi0].out_bytes as f64;
                let g = m.layers[hi0 + 1].grad_bytes as f64;
                committed_comm =
                    2.0 * (o + g) / w_best + 4.0 * p.storage.latency_s;
                cuts.push(hi0);
            }
            let sync_lb = if d > 1 {
                crate::collective::sync_time(
                    perf.sync_alg,
                    terms.param_bytes as f64,
                    d,
                    p.tier(tier0).bandwidth_bps,
                    p.storage.latency_s,
                )
            } else {
                0.0
            };
            let mut ctx = Dfs {
                perf,
                node_budget,
                d,
                mu,
                n_micro_global,
                alpha,
                suffix_min_s: &pre.suffix_min_s,
                suffix_max_fwd: &pre.suffix_max_fwd,
                suffix_max_bwd: &pre.suffix_max_bwd,
                suffix_min_gb,
                cuts,
                tiers: vec![tier0],
                committed_s: terms.fwd_s + terms.bwd_s,
                committed_gb: p.tier(tier0).mem_gb(),
                max_fc: terms.fwd_s,
                max_bc: terms.bwd_s,
                committed_comm,
                sync_lb,
                stats: &mut stats,
                best: &mut best,
                shared: Some(&shared),
            };
            ctx.go(hi0 + 1);
            (stats, best)
        });

    // Deterministic merge: packet order is the serial traversal order
    // and strict `<` keeps the FIRST achiever of the minimum — exactly
    // the leaf the serial DFS would have locked in. The greedy
    // incumbent merges LAST (it only matters when a binding budget
    // truncated every packet; on ties the packets' own leaves win, as
    // they do serially).
    let mut stats = SolveStats::default();
    let mut best: Option<(f64, Plan)> = None;
    for (s, b) in results {
        stats.absorb(&s);
        if let Some((j, plan)) = b {
            if best.as_ref().map(|(bj, _)| j < *bj).unwrap_or(true) {
                best = Some((j, plan));
            }
        }
    }
    if let Some((j, plan)) = greedy {
        if best.as_ref().map(|(bj, _)| j < *bj).unwrap_or(true) {
            best = Some((j, plan));
        }
    }

    stats.solve_time_s = start.elapsed().as_secs_f64();
    best.map(|(_, plan)| {
        let perf = perf.evaluate(&plan);
        (plan, perf, stats)
    })
}

struct Dfs<'b, 'a> {
    perf: &'b PerfModel<'a>,
    node_budget: u64,
    d: usize,
    mu: usize,
    n_micro_global: usize,
    alpha: (f64, f64),
    suffix_min_s: &'b [f64],
    suffix_max_fwd: &'b [f64],
    suffix_max_bwd: &'b [f64],
    suffix_min_gb: &'b [f64],
    cuts: Vec<usize>,
    tiers: Vec<usize>,
    committed_s: f64,
    committed_gb: f64,
    /// max committed per-stage fwd/bwd compute (for the (μ-1)·Δ bound)
    max_fc: f64,
    max_bc: f64,
    /// Σ over committed boundaries of their minimum transfer time
    committed_comm: f64,
    /// max over committed stages of their minimum sync time (d > 1)
    sync_lb: f64,
    stats: &'b mut SolveStats,
    best: &'b mut Option<(f64, Plan)>,
    /// Best-known bound shared across parallel packets (`None` on the
    /// serial path). Pruned against with STRICT `>` — the shared value
    /// is some feasible plan's objective, so a node whose bound merely
    /// *equals* it may still lead to the tie the serial search keeps.
    shared: Option<&'b SharedBound>,
}

impl Dfs<'_, '_> {
    /// Extend the partial plan whose next unassigned layer is `lo`.
    fn go(&mut self, lo: usize) {
        let m = self.perf.model;
        let p = self.perf.platform;
        let l = m.n_layers();
        self.stats.nodes += 1;
        if self.stats.nodes > self.node_budget {
            return;
        }

        if lo == l {
            // complete plan: exact evaluation
            self.stats.leaves += 1;
            let plan = Plan {
                cuts: self.cuts.clone(),
                dp: self.d,
                stage_tiers: self.tiers.clone(),
                n_micro_global: self.n_micro_global,
            };
            debug_assert!(plan.validate(m, p).is_ok());
            let (t_iter, c_iter) = self.perf.quick(&plan);
            let j = self.alpha.0 * c_iter + self.alpha.1 * t_iter;
            if self.best.as_ref().map(|(b, _)| j < *b).unwrap_or(true) {
                *self.best = Some((j, plan));
            }
            if let Some(shared) = self.shared {
                shared.tighten(j);
            }
            return;
        }

        // bound: committed + optimistic remainder.
        // t_iter ≥ t_f + max_s t_b^s ≥ Σ(fc+bc) + (μ-1)(Δ_f + Δ_b), and
        // Δ_f ≥ max(max committed stage fwd, any remaining layer's
        // fastest-tier fwd) (likewise backward).
        let local = self.best.as_ref().map(|(b, _)| *b);
        if local.is_some() || self.shared.is_some() {
            let delta_f = self.max_fc.max(self.suffix_max_fwd[lo]);
            let delta_b = self.max_bc.max(self.suffix_max_bwd[lo]);
            // β applies to every completion that has communication: any
            // partial with a committed stage (plus remaining layers) has
            // >= 2 stages, and any d > 1 plan syncs — admissible either way
            let beta_lb = if self.d > 1 || !self.tiers.is_empty() {
                p.beta
            } else {
                1.0
            };
            // compute is β-scaled; committed boundary transfers and the
            // largest committed stage's sync add on top (both appear in
            // t_f / max_s(t_b+t_s) regardless of later choices)
            let t_lb = beta_lb
                * (self.committed_s
                    + self.suffix_min_s[lo]
                    + (self.mu as f64 - 1.0) * (delta_f + delta_b))
                + self.committed_comm
                + self.sync_lb;
            let gb_lb = self.committed_gb + self.suffix_min_gb[lo];
            let c_lb =
                p.price_per_gb_s * (self.d as f64) * gb_lb * t_lb;
            let j_lb = self.alpha.0 * c_lb + self.alpha.1 * t_lb;
            // Local incumbents prune on `>=` (a tie already found in
            // THIS subtree's past keeps serial first-wins semantics);
            // the shared bound prunes on STRICT `>` only — see the
            // field's invariant.
            let prune_local = local.map(|b| j_lb >= b).unwrap_or(false);
            let prune_shared = self
                .shared
                .map(|s| j_lb > s.get())
                .unwrap_or(false);
            if prune_local || prune_shared {
                self.stats.pruned_bound += 1;
                return;
            }
        }

        // branch: this stage covers [lo..=hi] on tier j. Try larger tiers
        // first (good incumbents early: feasible + fast). The per-stage
        // terms come from the PerfModel's StageCache, so revisiting a
        // (range, tier) pair anywhere in the search is O(1).
        for hi in lo..l {
            for j in (0..p.n_tiers()).rev() {
                let terms = self.perf.stage_terms(lo, hi, j);
                // feasibility (3b)
                let sync_copies = if self.d == 1 { 2 } else { 4 };
                let need = (self.mu as u64) * terms.act_bytes
                    + terms.param_bytes * sync_copies
                    + p.base_mem_mb * 1024 * 1024;
                if need > p.tier(j).mem_bytes() {
                    self.stats.pruned_memory += 1;
                    continue; // smaller tiers will also fail
                }
                let stage_fwd = terms.fwd_s;
                let stage_bwd = terms.bwd_s;
                let stage_gb = p.tier(j).mem_gb();
                let (old_fc, old_bc) = (self.max_fc, self.max_bc);
                let (old_comm, old_sync) = (self.committed_comm, self.sync_lb);

                // admissible comm contribution of the boundary after `hi`
                // (raw best-tier bandwidth ≥ any effective bandwidth)
                let w_best = p
                    .tiers
                    .iter()
                    .map(|t| t.bandwidth_bps)
                    .fold(0.0f64, f64::max);
                if hi < l - 1 {
                    let o = m.layers[hi].out_bytes as f64;
                    let g = m.layers[hi + 1].grad_bytes as f64;
                    self.committed_comm += 2.0 * (o + g) / w_best
                        + 4.0 * p.storage.latency_s;
                    self.cuts.push(hi);
                }
                if self.d > 1 {
                    // t_iter ≥ ... + t_s of this stage; its tier is known,
                    // raw tier bandwidth ≥ effective → admissible
                    let sync = crate::collective::sync_time(
                        self.perf.sync_alg,
                        terms.param_bytes as f64,
                        self.d,
                        p.tier(j).bandwidth_bps,
                        p.storage.latency_s,
                    );
                    self.sync_lb = self.sync_lb.max(sync);
                }
                self.tiers.push(j);
                self.committed_s += stage_fwd + stage_bwd;
                self.committed_gb += stage_gb;
                self.max_fc = self.max_fc.max(stage_fwd);
                self.max_bc = self.max_bc.max(stage_bwd);

                self.go(hi + 1);

                self.max_fc = old_fc;
                self.max_bc = old_bc;
                self.committed_gb -= stage_gb;
                self.committed_s -= stage_fwd + stage_bwd;
                self.tiers.pop();
                self.sync_lb = old_sync;
                self.committed_comm = old_comm;
                if hi < l - 1 {
                    self.cuts.pop();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};

    #[test]
    fn finds_feasible_optimal_plan() {
        let p = PlatformSpec::aws_lambda();
        let m0 = zoo::amoebanet_d18(&p);
        let m = merge_layers(&m0, 6, MergeCriterion::Compute);
        let opt = CoOptimizer::new(&m, &p);
        let (plan, perf, stats) = opt.solve(16, (1.0, 2e-4)).unwrap();
        plan.validate(&m, &p).unwrap();
        assert!(perf.t_iter > 0.0);
        assert!(stats.leaves > 0);
        assert!(stats.solve_time_s < 60.0);
    }

    #[test]
    fn cost_only_weight_prefers_cheap_plans() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            6,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (_, cheap, _) = opt.solve(16, (1.0, 0.0)).unwrap();
        let (_, fast, _) = opt.solve(16, (0.0, 1.0)).unwrap();
        assert!(cheap.c_iter <= fast.c_iter + 1e-12);
        assert!(fast.t_iter <= cheap.t_iter + 1e-12);
    }

    #[test]
    fn beats_pure_data_parallelism_on_big_models() {
        // the headline claim: co-optimized pipeline beats the LambdaML
        // shape (max-memory pure DP) on large models
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::amoebanet_d36(&p),
            8,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (plan, perf, _) = opt.solve(16, (1.0, 2e-4)).unwrap();
        // compare with best feasible pure-DP plan at max tier
        let pm = PerfModel::new(&m, &p);
        let mut best_dp = f64::INFINITY;
        for d in [1usize, 2, 4, 8, 16] {
            if 16 % d != 0 {
                continue;
            }
            let cand = Plan {
                cuts: vec![],
                dp: d,
                stage_tiers: vec![p.max_tier()],
                n_micro_global: 16,
            };
            if cand.validate(&m, &p).is_ok() {
                best_dp = best_dp.min(pm.evaluate(&cand).t_iter);
            }
        }
        assert!(
            perf.t_iter < best_dp,
            "co-opt {} !< best pure dp {}",
            perf.t_iter,
            best_dp
        );
        assert!(plan.n_stages() > 1, "expected pipeline: {plan:?}");
    }

    #[test]
    fn exhaustive_small_case_agrees() {
        // brute force over ALL plans for a tiny model and check B&B
        // returns the same optimum
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            4,
            MergeCriterion::Compute,
        );
        let mut opt = CoOptimizer::new(&m, &p);
        opt.dp_options = vec![1, 2, 4];
        let alpha = (1.0, 1e-4);
        let (plan, perf, _) = opt.solve(8, alpha).unwrap();
        let j_bb = alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;

        let pm = PerfModel::new(&m, &p);
        let mut j_brute = f64::INFINITY;
        let l = m.n_layers();
        // enumerate all 2^(l-1) cut sets × tiers × d
        for mask in 0u32..(1 << (l - 1)) {
            let cuts: Vec<usize> =
                (0..l - 1).filter(|&i| mask & (1 << i) != 0).collect();
            let s = cuts.len() + 1;
            let mut tier_idx = vec![0usize; s];
            loop {
                for &d in &[1usize, 2, 4] {
                    if 8 % d != 0 {
                        continue;
                    }
                    let plan = Plan {
                        cuts: cuts.clone(),
                        dp: d,
                        stage_tiers: tier_idx.clone(),
                        n_micro_global: 8,
                    };
                    if plan.validate(&m, &p).is_ok() {
                        let perf = pm.evaluate(&plan);
                        let j =
                            alpha.0 * perf.c_iter + alpha.1 * perf.t_iter;
                        if j < j_brute {
                            j_brute = j;
                        }
                    }
                }
                // increment tier_idx (odometer)
                let mut k = 0;
                loop {
                    tier_idx[k] += 1;
                    if tier_idx[k] < p.n_tiers() {
                        break;
                    }
                    tier_idx[k] = 0;
                    k += 1;
                    if k == s {
                        break;
                    }
                }
                if k == s {
                    break;
                }
            }
        }
        assert!(
            (j_bb - j_brute).abs() < 1e-9 * j_brute.max(1.0),
            "B&B {j_bb} vs brute {j_brute} (plan {plan:?})"
        );
    }

    #[test]
    fn stage_cache_is_hot_in_search() {
        // thousands of DFS nodes revisit the same few hundred
        // (range, tier) stages: the memoized terms must serve the bulk
        // of lookups (the planner_search bench reports the same number)
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            6,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        opt.solve(16, (1.0, 2e-4)).unwrap();
        let cache = opt.perf.cache();
        assert!(cache.hits() > cache.misses(), "{cache:?}");
        assert!(
            cache.hit_rate() > 0.5,
            "hit rate {:.2} too low",
            cache.hit_rate()
        );
    }

    #[test]
    fn parallel_search_matches_serial_plan() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            4,
            MergeCriterion::Compute,
        );
        let perf = PerfModel::new(&m, &p);
        let dp = vec![1usize, 2, 4];
        for &alpha in &[(1.0, 0.0), (1.0, 1e-4), (0.0, 1.0)] {
            let a =
                solve_with(&perf, &dp, DEFAULT_NODE_BUDGET, 8, alpha);
            let b = solve_parallel(
                &perf,
                &dp,
                DEFAULT_NODE_BUDGET,
                8,
                alpha,
            );
            match (a, b) {
                (Some((pa, fa, _)), Some((pb, fb, _))) => {
                    assert_eq!(pa, pb, "plan diverged at {alpha:?}");
                    assert_eq!(
                        fa.t_iter.to_bits(),
                        fb.t_iter.to_bits(),
                        "perf diverged at {alpha:?}"
                    );
                }
                (None, None) => {}
                (a, b) => panic!(
                    "feasibility diverged at {alpha:?}: serial={} \
                     parallel={}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    }

    #[test]
    fn respects_dp_divisibility() {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(
            &zoo::resnet101(&p),
            4,
            MergeCriterion::Compute,
        );
        let opt = CoOptimizer::new(&m, &p);
        let (plan, _, _) = opt.solve(6, (1.0, 1e-4)).unwrap();
        assert!(6 % plan.dp == 0);
    }
}
