//! Weight sweep → Pareto frontier → recommendation (§5.1 "Recommendation").
//!
//! Each (α1, α2) pair yields one Pareto-optimal configuration; FuncPipe
//! then recommends the fastest configuration whose efficiency
//! `δ = (t_mc/t_p − 1) / (c_p/c_mc − 1) ≥ 0.8`, where (t_mc, c_mc) is the
//! minimum-cost configuration (weights (1, 0)).

use crate::model::Plan;
use crate::planner::perf_model::PlanPerf;

/// One evaluated configuration in a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub plan: Plan,
    pub perf: PlanPerf,
    pub weights: (f64, f64),
}

/// Run a solver closure for each weight pair; dedupes identical plans.
pub fn sweep<F>(weights: &[(f64, f64)], mut solve: F) -> Vec<SweepPoint>
where
    F: FnMut((f64, f64)) -> Option<(Plan, PlanPerf)>,
{
    let mut out: Vec<SweepPoint> = Vec::new();
    for &w in weights {
        if let Some((plan, perf)) = solve(w) {
            if !out.iter().any(|p| p.plan == plan) {
                out.push(SweepPoint { plan, perf, weights: w });
            }
        }
    }
    out
}

/// Whether metric pair `b` dominates `a` (strictly better in one of
/// (t, c), no worse in the other).
fn dominates(b: (f64, f64), a: (f64, f64)) -> bool {
    (b.0 < a.0 - 1e-12 && b.1 <= a.1 + 1e-12)
        || (b.1 < a.1 - 1e-12 && b.0 <= a.0 + 1e-12)
}

/// Non-domination flags over `(t, c)` metric pairs — the generic core
/// behind [`pareto_front`] and
/// [`PlanOutcome::frontier_flags`](super::PlanOutcome::frontier_flags),
/// which feeds it either the deterministic `(t_iter, c_iter)` or the
/// scenario-robust worst/mean metric.
pub fn pareto_flags(metrics: &[(f64, f64)]) -> Vec<bool> {
    metrics
        .iter()
        .map(|&a| !metrics.iter().any(|&b| dominates(b, a)))
        .collect()
}

/// The δ ≥ 0.8 recommendation rule over `(t, c)` metric pairs,
/// restricted to the candidate indices in `idxs` (must contain the
/// minimum-cost point, i.e. weights (1, 0) should be in the sweep).
/// Returns the winning index.
pub fn recommend_among(metrics: &[(f64, f64)], idxs: &[usize]) -> Option<usize> {
    let mc = idxs
        .iter()
        .copied()
        .min_by(|&a, &b| metrics[a].1.partial_cmp(&metrics[b].1).unwrap())?;
    let (t_mc, c_mc) = metrics[mc];
    let mut cands: Vec<usize> = idxs
        .iter()
        .copied()
        .filter(|&i| {
            let dt = t_mc / metrics[i].0 - 1.0;
            let dc = metrics[i].1 / c_mc - 1.0;
            if dc <= 1e-12 {
                // no extra cost: always efficient
                true
            } else {
                dt / dc >= 0.8
            }
        })
        .collect();
    cands.sort_by(|&a, &b| metrics[a].0.partial_cmp(&metrics[b].0).unwrap());
    cands.first().copied()
}

fn metrics_of(points: &[SweepPoint]) -> Vec<(f64, f64)> {
    points.iter().map(|p| (p.perf.t_iter, p.perf.c_iter)).collect()
}

/// Pareto-filter on (t_iter, c_iter): keep points not dominated by any
/// other (strictly better in one dimension, no worse in the other).
pub fn pareto_front(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let flags = pareto_flags(&metrics_of(points));
    points
        .iter()
        .zip(flags)
        .filter(|(_, keep)| *keep)
        .map(|(p, _)| p.clone())
        .collect()
}

/// The paper's recommendation rule over a sweep (must contain the
/// minimum-cost point, i.e. weights (1,0) should be in the sweep).
pub fn recommend(points: &[SweepPoint]) -> Option<SweepPoint> {
    let metrics = metrics_of(points);
    let idxs: Vec<usize> = (0..points.len()).collect();
    recommend_among(&metrics, &idxs).map(|i| points[i].clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(t: f64, c: f64) -> SweepPoint {
        SweepPoint {
            plan: Plan {
                cuts: vec![],
                dp: 1,
                stage_tiers: vec![(t * 10.0) as usize % 8],
                n_micro_global: (c * 1000.0) as usize + 1,
            },
            perf: PlanPerf {
                t_iter: t,
                c_iter: c,
                t_fwd: t / 2.0,
                t_bwd_sync: t / 2.0,
                compute_s: t * 0.6,
                flush_s: t * 0.3,
                sync_s: t * 0.1,
                total_mem_gb: 1.0,
            },
            weights: (1.0, 0.0),
        }
    }

    #[test]
    fn pareto_removes_dominated() {
        let pts = vec![pt(10.0, 1.0), pt(5.0, 2.0), pt(12.0, 3.0), pt(4.0, 4.0)];
        let front = pareto_front(&pts);
        let ts: Vec<f64> = front.iter().map(|p| p.perf.t_iter).collect();
        assert!(ts.contains(&10.0));
        assert!(ts.contains(&5.0));
        assert!(ts.contains(&4.0));
        assert!(!ts.contains(&12.0)); // dominated by (5, 2)
    }

    #[test]
    fn recommend_prefers_efficient_speedups() {
        // mc = (10s, $1); candidate A: 5s at $2 → δ = (10/5-1)/(2/1-1) = 1
        // ≥ 0.8 — recommended; candidate B: 8s at $3 → δ = 0.125 — no.
        let pts = vec![pt(10.0, 1.0), pt(5.0, 2.0), pt(8.0, 3.0)];
        let rec = recommend(&pts).unwrap();
        assert_eq!(rec.perf.t_iter, 5.0);
    }

    #[test]
    fn recommend_falls_back_to_min_cost() {
        // the only faster point is wildly inefficient
        let pts = vec![pt(10.0, 1.0), pt(9.5, 10.0)];
        let rec = recommend(&pts).unwrap();
        assert_eq!(rec.perf.t_iter, 10.0);
    }

    #[test]
    fn sweep_dedupes() {
        let mut calls = 0;
        let pts = sweep(&[(1.0, 0.0), (1.0, 1.0)], |_| {
            calls += 1;
            Some((
                Plan {
                    cuts: vec![],
                    dp: 1,
                    stage_tiers: vec![0],
                    n_micro_global: 4,
                },
                pt(1.0, 1.0).perf,
            ))
        });
        assert_eq!(calls, 2);
        assert_eq!(pts.len(), 1);
    }
}
