//! Parallel, byte-deterministic scoring of plan finalists — the work
//! queue behind robust and SLO re-scoring (PR 8).
//!
//! Every scoring replay is a pure function of `(plan, scenario|traffic,
//! seed)`, so the `(plan, seed)` job grid can fan out over scoped
//! worker threads (sized by [`exec::pool_size`](crate::exec::pool_size))
//! with NO effect on the bytes of any report: workers claim jobs from an
//! atomic counter in whatever order the scheduler allows, but results
//! are merged back by job index and **reduced strictly in `(plan,
//! seed)` order** — the exact accumulation order of the historical
//! serial loops, so worst/mean aggregates are bit-identical to the
//! serial reference no matter the interleaving.
//!
//! The module also owns [`PlanKey`] — the canonical, collision-free
//! encoding of a [`Plan`] used everywhere a plan is a lookup key
//! (dedup in the race scoring memo, candidate dedup in the strategies,
//! cross-strategy pooling). It replaces the historical
//! `Vec<(Plan, Score)>` linear scans (O(n²) with a whole-`Plan` clone
//! per candidate) with a hash map over a `Box<[u64]>` key.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use crate::model::Plan;
use crate::pipeline::simulate_iteration_scenario;
use crate::planner::perf_model::PerfModel;
use crate::planner::strategy::{
    RobustScore, RobustSpec, SloScore, SloSpec, SLO_REPLAY_DURATION_S,
};
use crate::serve::{prepare_serve, serve_prepared, ServeOptions};

/// Canonical hashed key of a [`Plan`]: the plan's decision variables
/// packed into one `u64` slice. The encoding is *exact* (no hashing at
/// construction, so no collisions — two plans share a key iff they are
/// equal) and prefix-free: `dp`, `n_micro_global` and the cut count
/// come first, so `cuts` and `stage_tiers` can never alias across
/// plans with different shapes. `Ord` gives scoring reductions a
/// deterministic plan order independent of hash-map iteration.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey(Box<[u64]>);

impl PlanKey {
    pub fn of(plan: &Plan) -> Self {
        let mut v =
            Vec::with_capacity(3 + plan.cuts.len() + plan.stage_tiers.len());
        v.push(plan.dp as u64);
        v.push(plan.n_micro_global as u64);
        v.push(plan.cuts.len() as u64);
        v.extend(plan.cuts.iter().map(|&c| c as u64));
        v.extend(plan.stage_tiers.iter().map(|&t| t as u64));
        PlanKey(v.into_boxed_slice())
    }
}

/// Insertion-ordered dedup set of plans keyed by [`PlanKey`]: O(1)
/// membership, one `Plan` clone per *distinct* plan (the historical
/// memos cloned per candidate). The insertion order is the reduction
/// order of the batch scorers, so it must be deterministic — callers
/// insert in (strategy, candidate) order.
#[derive(Debug, Default)]
pub struct PlanSet {
    idx: HashMap<PlanKey, usize>,
    plans: Vec<Plan>,
}

impl PlanSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (cloning only on first sight); returns the plan's index
    /// and whether it was newly added.
    pub fn insert(&mut self, plan: &Plan) -> (usize, bool) {
        match self.idx.entry(PlanKey::of(plan)) {
            Entry::Occupied(e) => (*e.get(), false),
            Entry::Vacant(e) => {
                let i = self.plans.len();
                e.insert(i);
                self.plans.push(plan.clone());
                (i, true)
            }
        }
    }

    /// Index of a previously inserted plan.
    pub fn index_of(&self, plan: &Plan) -> Option<usize> {
        self.idx.get(&PlanKey::of(plan)).copied()
    }

    /// The distinct plans, in insertion order.
    pub fn plans(&self) -> &[Plan] {
        &self.plans
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }
}

/// Fan `n_jobs` independent evaluations of `f` over scoped worker
/// threads (at most [`exec::pool_size`](crate::exec::pool_size), never
/// more threads than jobs) and return the results **in job order**.
/// Workers claim indices from one atomic counter, so load balances
/// dynamically; each worker keeps `(index, result)` pairs privately and
/// the merge sorts by index, so the output is independent of
/// interleaving. With one job (or one core) this degrades to the plain
/// serial loop — no threads spawned.
pub(crate) fn run_jobs<T, F>(n_jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = crate::exec::pool_size().min(n_jobs).max(1);
    if threads <= 1 {
        return (0..n_jobs).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_jobs {
                            break;
                        }
                        mine.push((i, f(i)));
                    }
                    mine
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scoring worker panicked"))
            .collect()
    });
    let mut all: Vec<(usize, T)> = parts.into_iter().flatten().collect();
    all.sort_by_key(|(i, _)| *i);
    debug_assert_eq!(all.len(), n_jobs);
    all.into_iter().map(|(_, t)| t).collect()
}

/// Score every plan under `spec.seeds` seeded DES replays of the
/// scenario, fanning the `(plan, seed)` grid over the worker pool.
/// Returns one [`RobustScore`] per plan, in plan order. Each replay is
/// the same `simulate_iteration_scenario` call the serial path made,
/// and the per-plan reduction walks seeds `1..=n` in order, so every
/// score is bit-identical to the serial reference.
pub fn robust_scores(
    perf: &PerfModel<'_>,
    plans: &[Plan],
    spec: &RobustSpec,
) -> Vec<RobustScore> {
    let seeds = spec.seeds;
    let results: Vec<(f64, f64)> =
        run_jobs(plans.len() * seeds, |job| {
            let plan = &plans[job / seeds];
            let seed = (job % seeds) as u64 + 1;
            let sim = simulate_iteration_scenario(
                perf.model,
                perf.platform,
                plan,
                perf.sync_alg,
                &spec.scenario,
                seed,
            );
            (sim.t_iter, sim.c_iter)
        });
    results
        .chunks(seeds)
        .map(|per_seed| {
            let (mut worst_t, mut worst_c) = (0.0f64, 0.0f64);
            let (mut sum_t, mut sum_c) = (0.0f64, 0.0f64);
            for &(t, c) in per_seed {
                worst_t = worst_t.max(t);
                worst_c = worst_c.max(c);
                sum_t += t;
                sum_c += c;
            }
            let n = seeds as f64;
            RobustScore {
                worst_t,
                worst_c,
                mean_t: sum_t / n,
                mean_c: sum_c / n,
            }
        })
        .collect()
}

/// Score every plan under `spec.seeds` seeded serving replays, fanning
/// the `(plan, seed)` grid over the worker pool. The per-plan serving
/// pipeline (stage byte terms, service times, batch cap) is derived
/// ONCE via [`prepare_serve`] and shared by all of that plan's seeds —
/// the serial path re-derived it per seed. Returns one [`SloScore`]
/// per plan in plan order; on failure, the first error in `(plan,
/// seed)` order (the serial loop's error).
pub fn slo_scores(
    perf: &PerfModel<'_>,
    plans: &[Plan],
    spec: &SloSpec,
) -> Result<Vec<SloScore>> {
    let preps = plans
        .iter()
        .map(|p| prepare_serve(perf, p))
        .collect::<Result<Vec<_>>>()?;
    let seeds = spec.seeds;
    let results: Vec<Result<(f64, f64, bool)>> =
        run_jobs(plans.len() * seeds, |job| {
            let prep = &preps[job / seeds];
            let seed = (job % seeds) as u64 + 1;
            let mut opts = ServeOptions::new(spec.traffic.clone(), seed);
            opts.duration_s = SLO_REPLAY_DURATION_S;
            let out = serve_prepared(perf, prep, &opts)?;
            Ok((out.p99_ms, out.cost_per_1k_usd, out.completed > 0))
        });
    results
        .chunks(seeds)
        .map(|per_seed| {
            let mut worst_p99 = 0.0f64;
            let mut sum_cost = 0.0f64;
            let mut all_served = true;
            for r in per_seed {
                let &(p99, cost, served) = r.as_ref().map_err(|e| {
                    anyhow::anyhow!("{e:#}")
                })?;
                worst_p99 = worst_p99.max(p99);
                sum_cost += cost;
                all_served &= served;
            }
            Ok(SloScore {
                p99_ms: worst_p99,
                cost_per_1k_usd: sum_cost / seeds as f64,
                feasible: all_served && worst_p99 <= spec.p99_ms,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{merge_layers, zoo, MergeCriterion};
    use crate::planner::strategy::RobustRank;
    use crate::platform::PlatformSpec;
    use crate::serve::{serve_plan, TrafficSpec};
    use crate::simcore::ScenarioSpec;

    fn fixture() -> (crate::model::ModelProfile, PlatformSpec) {
        let p = PlatformSpec::aws_lambda();
        let m = merge_layers(&zoo::resnet101(&p), 4, MergeCriterion::Compute);
        (m, p)
    }

    fn some_plans(
        perf: &PerfModel<'_>,
    ) -> Vec<Plan> {
        let mut req = crate::planner::strategy::PlanRequest::new(16);
        req.dp_options = vec![1, 2];
        let out =
            crate::planner::strategy::solve_request("sweep", perf, &req)
                .unwrap();
        out.candidates.into_iter().map(|c| c.plan).collect()
    }

    #[test]
    fn plan_key_is_exact_and_shape_safe() {
        let a = Plan {
            cuts: vec![3],
            dp: 2,
            stage_tiers: vec![1, 2],
            n_micro_global: 8,
        };
        let b = Plan { cuts: vec![], dp: 2, stage_tiers: vec![3], n_micro_global: 8 };
        assert_eq!(PlanKey::of(&a), PlanKey::of(&a.clone()));
        assert_ne!(PlanKey::of(&a), PlanKey::of(&b));
        // shape ambiguity: same flattened numbers, different split
        let c = Plan {
            cuts: vec![3, 1],
            dp: 2,
            stage_tiers: vec![2],
            n_micro_global: 8,
        };
        assert_ne!(PlanKey::of(&a), PlanKey::of(&c));
    }

    #[test]
    fn plan_set_dedups_in_insertion_order() {
        let mk = |dp: usize| Plan {
            cuts: vec![],
            dp,
            stage_tiers: vec![0],
            n_micro_global: 8,
        };
        let mut set = PlanSet::new();
        assert!(set.is_empty());
        assert_eq!(set.insert(&mk(1)), (0, true));
        assert_eq!(set.insert(&mk(2)), (1, true));
        assert_eq!(set.insert(&mk(1)), (0, false));
        assert_eq!(set.len(), 2);
        assert_eq!(set.index_of(&mk(2)), Some(1));
        assert_eq!(set.index_of(&mk(4)), None);
        assert_eq!(set.plans()[0].dp, 1);
        assert_eq!(set.plans()[1].dp, 2);
    }

    #[test]
    fn run_jobs_returns_results_in_job_order() {
        let out = run_jobs(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        assert!(run_jobs(0, |i| i).is_empty());
    }

    #[test]
    fn robust_scores_match_the_serial_reference_bit_for_bit() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let plans = some_plans(&perf);
        assert!(!plans.is_empty());
        let spec = RobustSpec {
            scenario: ScenarioSpec::parse("straggler+jitter").unwrap(),
            seeds: 4,
            rank: RobustRank::Worst,
        };
        let par = robust_scores(&perf, &plans, &spec);
        for (plan, score) in plans.iter().zip(&par) {
            // the serial reference: seeds 1..=n in order
            let (mut worst_t, mut worst_c) = (0.0f64, 0.0f64);
            let (mut sum_t, mut sum_c) = (0.0f64, 0.0f64);
            for seed in 1..=spec.seeds as u64 {
                let sim = simulate_iteration_scenario(
                    &m, &p, plan, perf.sync_alg, &spec.scenario, seed,
                );
                worst_t = worst_t.max(sim.t_iter);
                worst_c = worst_c.max(sim.c_iter);
                sum_t += sim.t_iter;
                sum_c += sim.c_iter;
            }
            let n = spec.seeds as f64;
            assert_eq!(score.worst_t.to_bits(), worst_t.to_bits());
            assert_eq!(score.worst_c.to_bits(), worst_c.to_bits());
            assert_eq!(score.mean_t.to_bits(), (sum_t / n).to_bits());
            assert_eq!(score.mean_c.to_bits(), (sum_c / n).to_bits());
        }
    }

    #[test]
    fn slo_scores_match_the_serial_reference_bit_for_bit() {
        let (m, p) = fixture();
        let perf = PerfModel::new(&m, &p);
        let plans = some_plans(&perf);
        let spec = SloSpec {
            p99_ms: 120_000.0,
            traffic: TrafficSpec::parse("poisson:300").unwrap(),
            seeds: 2,
        };
        let par = slo_scores(&perf, &plans, &spec).unwrap();
        for (plan, score) in plans.iter().zip(&par) {
            let mut worst_p99 = 0.0f64;
            let mut sum_cost = 0.0f64;
            for seed in 1..=spec.seeds as u64 {
                let mut opts =
                    ServeOptions::new(spec.traffic.clone(), seed);
                opts.duration_s = SLO_REPLAY_DURATION_S;
                let out = serve_plan(&perf, plan, &opts).unwrap();
                worst_p99 = worst_p99.max(out.p99_ms);
                sum_cost += out.cost_per_1k_usd;
            }
            assert_eq!(score.p99_ms.to_bits(), worst_p99.to_bits());
            assert_eq!(
                score.cost_per_1k_usd.to_bits(),
                (sum_cost / spec.seeds as f64).to_bits()
            );
        }
    }
}
