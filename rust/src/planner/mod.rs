//! §3.4: co-optimization of model partition and resource allocation —
//! one [`Planner`] API over five interchangeable strategies.
//!
//! A [`PlanRequest`] (weight sweep, micro-batch budget, dp options,
//! node/time budget, optional scenario-robustness spec) goes in; a
//! [`PlanOutcome`] (deduped candidates with [`PlanPerf`], solve stats,
//! Pareto frontier, δ ≥ 0.8 recommendation, strategy provenance) comes
//! out. The strategies live behind the string-keyed registry in
//! [`strategy`] ([`strategy_by_name`], [`solve_request`], [`race`]):
//!
//! | key | module | what it is |
//! |---|---|---|
//! | `bnb` | [`optimizer`] | FuncPipe's exact branch-and-bound over (partition, d, per-stage tier) — the default |
//! | `miqp` | [`miqp`] | direct solver over the paper's binary decision variables (x_i, y_k, z_{i,j}); replaces Gurobi (DESIGN.md §7) and certifies `bnb` |
//! | `bayes` | [`bayes`] | CherryPick-style GP + expected-improvement baseline, seeded and deterministic |
//! | `tpdmp` | [`tpdmp`] | the TPDMP baseline (§5.6): throughput-max partition under a fixed-resource grid |
//! | `sweep` | [`strategy`] | balanced-partition × uniform-tier × dp configuration grid under the closed-form model |
//!
//! Every strategy reads the same [`PerfModel`] (closed-form §3.4.2
//! model + memoizing, hash-sharded [`StageCache`]); `plan --strategy
//! all` races them in parallel threads over ONE shared model so the
//! cache warms once. Robust/SLO re-scoring and the default `bnb`
//! search are themselves parallel — [`score`] owns the
//! byte-deterministic `(plan, seed)` scoring work-queue and the
//! canonical [`PlanKey`], and [`optimizer::solve_parallel`] the
//! work-sharing branch-and-bound — which is what makes a full
//! `--strategy all` + robust + SLO plan cheap enough to invoke
//! *mid-run* (the SMLT re-planning loop). [`pareto`] keeps the generic
//! frontier/δ-rule plumbing (also used by the legacy sweep API the
//! examples exercise), and [`perf_model`] the closed-form iteration
//! time/cost model (§3.4.2 + App. B) every strategy shares.

pub mod bayes;
pub mod miqp;
pub mod optimizer;
pub mod pareto;
pub mod perf_model;
pub mod score;
pub mod strategy;
pub mod tpdmp;

pub use optimizer::{CoOptimizer, SolveStats};
pub use pareto::{
    pareto_flags, pareto_front, recommend, recommend_among, sweep, SweepPoint,
};
pub use perf_model::{PerfModel, PlanPerf, StageCache, StageTerms};
pub use score::{robust_scores, slo_scores, PlanKey, PlanSet};
pub use strategy::{
    race, solve_request, strategy_by_name, PlanCandidate, PlanOutcome,
    PlanRequest, Planner, RobustRank, RobustScore, RobustSpec, SloScore,
    SloSpec, STRATEGIES,
};

/// Weight pairs (α1 cost-weight, α2 time-weight) tracing the Pareto
/// frontier. The paper's magnitudes (1, 2^16…) are tied to its internal
/// cost unit; re-expressed here for $-and-seconds so the four points
/// produce distinct speed/cost trade-offs on every zoo model.
pub const DEFAULT_WEIGHTS: [(f64, f64); 4] =
    [(1.0, 0.0), (1.0, 2e-5), (1.0, 2e-4), (1.0, 2e-3)];

/// Default candidate data-parallel degrees (`D` in §3.4.1). ONE
/// definition searched by every strategy — historically each solver
/// hardcoded its own copy — and overridable per session via the
/// `dp_options` config key / `--dp-options` flag ([`PlanRequest`]
/// validates each degree against the platform's concurrency cap).
pub const DEFAULT_DP_OPTIONS: [usize; 6] = [1, 2, 4, 8, 16, 32];
