//! §3.4: co-optimization of model partition and resource allocation.
//!
//! * [`perf_model`] — the closed-form iteration time/cost model
//!   (§3.4.2 + App. B) shared by every optimizer below;
//! * [`optimizer`] — FuncPipe's exact branch-and-bound co-optimizer over
//!   (partition, data-parallel degree, per-stage memory tier);
//! * [`miqp`] — a direct solver over the paper's binary decision variables
//!   (x_i, y_k, z_{i,j}); replaces Gurobi (DESIGN.md §7), cross-checks
//!   [`optimizer`];
//! * [`tpdmp`] — the TPDMP baseline (§5.6): throughput-maximal partition
//!   under fixed resources + grid search over allocations;
//! * [`bayes`] — Bayesian-optimization baseline: GP + expected improvement
//!   over the joint encoded space;
//! * [`pareto`] — weight sweep, Pareto frontier and the paper's δ≥0.8
//!   recommendation rule.

pub mod bayes;
pub mod miqp;
pub mod optimizer;
pub mod pareto;
pub mod perf_model;
pub mod tpdmp;

pub use optimizer::{CoOptimizer, SolveStats};
pub use pareto::{pareto_front, recommend, sweep, SweepPoint};
pub use perf_model::{PerfModel, PlanPerf, StageCache, StageTerms};

/// Weight pairs (α1 cost-weight, α2 time-weight) tracing the Pareto
/// frontier. The paper's magnitudes (1, 2^16…) are tied to its internal
/// cost unit; re-expressed here for $-and-seconds so the four points
/// produce distinct speed/cost trade-offs on every zoo model.
pub const DEFAULT_WEIGHTS: [(f64, f64); 4] =
    [(1.0, 0.0), (1.0, 2e-5), (1.0, 2e-4), (1.0, 2e-3)];
