//! `simcore` — the one discrete-event simulation core behind the
//! performance model, the pipeline DES and the collective flow
//! simulations.
//!
//! The repo previously carried three divergent timing engines that had
//! to agree but shared no code: the closed-form planner model, the
//! hand-rolled event loop in `pipeline/simulate.rs`, and five
//! near-duplicate flow schedules in `collective/sim.rs`. They now share
//! one substrate:
//!
//! * [`graph`] — the declarative [`FlowGraph`]: nodes (compute /
//!   transfer / fixed occupancy) over per-worker uplink, downlink, CPU
//!   and virtual-channel [`Resource`]s, with per-resource capacities,
//!   an optional storage-side aggregate cap, and per-operation latency;
//! * [`engine`] — [`execute`]: max-min fair progressive filling over
//!   the active set, exact event advancement, deterministic
//!   tie-breaking (id-ordered scans; identical input ⇒ bit-identical
//!   output);
//! * [`scenario`] — [`ScenarioModel`]: seeded cold-start / straggler /
//!   bandwidth-jitter perturbations applied to a graph before
//!   execution.
//!
//! Producers emit graphs; the engine owns time:
//! [`collective::sim`](crate::collective::sim) emits each sync
//! algorithm's flow schedule (chunked and unchunked are the same graph
//! at different granularity),
//! [`pipeline::simulate`](crate::pipeline::simulate) translates a
//! [`Schedule`](crate::pipeline::Schedule) plus boundary transfers, and
//! [`FlowSim`](crate::platform::FlowSim) is a thin compatibility facade.
//! The closed-form [`PerfModel`](crate::planner::PerfModel) stays
//! closed-form but shares the same per-stage terms through its
//! memoizing `StageCache`.

pub mod engine;
pub mod graph;
pub mod scenario;

pub use engine::{allocate_rates, execute, execute_full, SimOutcome};
pub use graph::{FlowGraph, Node, NodeId, OpKind, Resource};
pub use scenario::{
    cold_start_delays, decay_curve, straggler_factors, ScenarioModel,
    ScenarioSpec, BANDWIDTH_DECAY_TAG, BANDWIDTH_JITTER_TAG, COLD_START_TAG,
    COLD_START_STORM_TAG, DECAY_PROBE_STEP, FLAKY_NETWORK_TAG,
    SPOT_REVOCATION_TAG, STRAGGLER_TAG,
};
