//! The discrete-event executor: one event loop for every simulation in
//! the repo (collective flow schedules, the pipeline DES, FlowSim).
//!
//! Semantics (a faithful generalization of the two engines it replaced):
//!
//! * a node becomes *ready* `delay` seconds after its last dependency
//!   finishes (roots: after its absolute `ready` time), but never before
//!   its worker's start offset;
//! * active nodes share resources **max-min fairly** by progressive
//!   filling, re-run whenever the active set changes; each resource is
//!   one constraint (its capacity over its active members) and the
//!   optional aggregate cap is one more constraint over all active
//!   transfers;
//! * time advances to the earliest of (a) the first completion at the
//!   current rates, (b) the next readiness instant — so rate changes are
//!   exact, not sampled;
//! * ties break deterministically: nodes are scanned, completed and
//!   resolved in id order, and constraints are assembled in first-seen
//!   order — identical inputs give bit-identical outputs on every run
//!   and platform.

use super::graph::{FlowGraph, OpKind};

/// Execution result: per-node finish times plus the makespan.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Finish instant of each node, indexed by [`NodeId`](super::NodeId).
    pub finish: Vec<f64>,
    /// Latest finish over all nodes (0.0 for an empty graph).
    pub makespan: f64,
}

/// Run `graph` to completion of every node.
///
/// Panics on a deadlocked graph (a dependency cycle, which the builders
/// cannot produce, or a zero-capacity resource with pending work).
pub fn execute(graph: &FlowGraph) -> SimOutcome {
    let n = graph.nodes.len();
    let mut remaining: Vec<f64> = graph.nodes.iter().map(|x| x.work).collect();
    let mut finish: Vec<Option<f64>> = vec![None; n];
    // resolved readiness: known immediately for roots, filled in as
    // dependencies complete
    let mut ready: Vec<Option<f64>> = (0..n)
        .map(|i| {
            let node = &graph.nodes[i];
            node.deps.is_empty().then(|| {
                (node.ready + node.delay).max(graph.worker_start(node.worker))
            })
        })
        .collect();

    let mut t = 0.0f64;
    let mut done = 0usize;
    let mut makespan = 0.0f64;

    while done < n {
        // active set, in id order (deterministic tie-breaking)
        let active: Vec<usize> = (0..n)
            .filter(|&i| {
                finish[i].is_none()
                    && ready[i].map(|r| r <= t + 1e-12).unwrap_or(false)
            })
            .collect();

        // zero-work nodes complete the instant they are ready
        let mut completed: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| remaining[i] <= 1e-12)
            .collect();

        if completed.is_empty() && !active.is_empty() {
            let rates = allocate_rates(graph, &active);

            // earliest completion at these rates
            let mut dt = f64::INFINITY;
            for (k, &i) in active.iter().enumerate() {
                if rates[k] > 1e-12 {
                    dt = dt.min(remaining[i] / rates[k]);
                }
            }
            // ... capped by the next readiness instant
            let next_ready = (0..n)
                .filter(|&i| finish[i].is_none())
                .filter_map(|i| ready[i])
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            if next_ready.is_finite() {
                dt = dt.min(next_ready - t);
            }
            assert!(
                dt.is_finite() && dt > 0.0,
                "simcore: no progress possible at t={t} ({} unfinished)",
                n - done
            );

            for (k, &i) in active.iter().enumerate() {
                remaining[i] -= rates[k] * dt;
            }
            t += dt;

            completed = active
                .iter()
                .copied()
                .filter(|&i| {
                    // scale-aware completion snap: work is bytes for
                    // transfers and seconds for compute, so an absolute
                    // epsilon would bind differently per class
                    remaining[i] <= 1e-9 * graph.nodes[i].work.max(1.0)
                })
                .collect();
        } else if completed.is_empty() {
            // nothing running: jump to the next readiness instant
            let next_ready = (0..n)
                .filter(|&i| finish[i].is_none())
                .filter_map(|i| ready[i])
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            assert!(
                next_ready.is_finite(),
                "simcore: deadlock with {} nodes unfinished",
                n - done
            );
            t = next_ready;
            continue;
        }

        for &i in &completed {
            remaining[i] = 0.0;
            finish[i] = Some(t);
            makespan = makespan.max(t);
        }
        done += completed.len();

        // resolve newly-ready dependents (id order)
        for i in 0..n {
            if ready[i].is_some() || finish[i].is_some() {
                continue;
            }
            let node = &graph.nodes[i];
            let mut all = true;
            let mut latest: f64 = 0.0;
            for &d in &node.deps {
                match finish[d] {
                    Some(f) => latest = latest.max(f),
                    None => {
                        all = false;
                        break;
                    }
                }
            }
            if all {
                ready[i] = Some(
                    (latest + node.delay).max(graph.worker_start(node.worker)),
                );
            }
        }
    }

    SimOutcome {
        finish: finish.into_iter().map(|f| f.unwrap_or(0.0)).collect(),
        makespan,
    }
}

/// Max-min fair rates for the `active` node set by progressive filling
/// over the resource constraints (plus the aggregate transfer cap).
///
/// Public because it is THE allocator: the engine calls it every time
/// the active set changes, and `platform::network::max_min_rates`
/// (the historical entry point the property tests exercise) is an
/// adapter over it — there is exactly one max-min implementation in
/// the repo.
pub fn allocate_rates(graph: &FlowGraph, active: &[usize]) -> Vec<f64> {
    let na = active.len();
    let mut rates = vec![0.0f64; na];
    if na == 0 {
        return rates;
    }

    // constraints in deterministic first-seen order; members index into
    // `active`/`rates`
    let mut cons: Vec<(f64, Vec<usize>)> = Vec::new();
    let mut rmap: std::collections::HashMap<super::Resource, usize> =
        std::collections::HashMap::new();
    for (k, &i) in active.iter().enumerate() {
        for &r in &graph.nodes[i].resources {
            let ci = *rmap.entry(r).or_insert_with(|| {
                cons.push((graph.capacity(r), Vec::new()));
                cons.len() - 1
            });
            cons[ci].1.push(k);
        }
    }
    if let Some(cap) = graph.aggregate_cap {
        let members: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|&(_, &i)| graph.nodes[i].kind == OpKind::Transfer)
            .map(|(k, _)| k)
            .collect();
        if !members.is_empty() {
            cons.push((cap, members));
        }
    }

    let mut alive = vec![true; na];
    let mut used = vec![0.0f64; cons.len()];
    let mut n_alive = na;

    while n_alive > 0 {
        // bottleneck: smallest equal increment saturating a constraint
        let mut best_inc = f64::INFINITY;
        for (ci, (cap, members)) in cons.iter().enumerate() {
            let k = members.iter().filter(|&&m| alive[m]).count();
            if k == 0 {
                continue;
            }
            let inc = (cap - used[ci]) / k as f64;
            if inc < best_inc {
                best_inc = inc;
            }
        }
        if !best_inc.is_finite() {
            break; // node with no constraint: cannot happen by construction
        }
        let best_inc = best_inc.max(0.0);

        for (m, r) in rates.iter_mut().enumerate() {
            if alive[m] {
                *r += best_inc;
            }
        }
        for (ci, (_, members)) in cons.iter().enumerate() {
            let k = members.iter().filter(|&&m| alive[m]).count();
            used[ci] += best_inc * k as f64;
        }

        // freeze members of saturated constraints (scale-aware epsilon:
        // capacities span 1.0 CPU units to 1e9 byte/s links)
        let mut froze = false;
        for (ci, (cap, members)) in cons.iter().enumerate() {
            if used[ci] >= cap - 1e-9 * cap.max(1.0) {
                for &m in members {
                    if alive[m] {
                        alive[m] = false;
                        n_alive -= 1;
                        froze = true;
                    }
                }
            }
        }
        if !froze {
            break; // numerical safety, mirrors the old allocator
        }
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::super::{FlowGraph, Node, Resource};
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * b.abs().max(1.0)
    }

    #[test]
    fn serial_chain_sums_work() {
        let mut g = FlowGraph::new();
        let a = g.add(Node::compute(0, 2.0));
        let b = g.add(Node::compute(0, 3.0).after(vec![a]));
        let out = execute(&g);
        assert!(close(out.finish[a], 2.0));
        assert!(close(out.finish[b], 5.0));
        assert!(close(out.makespan, 5.0));
    }

    #[test]
    fn shared_resource_is_fair() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        let a = g.add(Node::transfer(0, true, 500.0));
        let b = g.add(Node::transfer(0, true, 500.0));
        let out = execute(&g);
        assert!(close(out.finish[a], 10.0));
        assert!(close(out.finish[b], 10.0));
    }

    #[test]
    fn duplex_links_are_independent() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(0), 100.0);
        let up = g.add(Node::transfer(0, true, 1000.0));
        let down = g.add(Node::transfer(0, false, 1000.0));
        let out = execute(&g);
        assert!(close(out.finish[up], 10.0));
        assert!(close(out.finish[down], 10.0));
    }

    #[test]
    fn aggregate_cap_spans_transfers_but_not_compute() {
        let mut g = FlowGraph::new();
        for w in 0..4 {
            g.set_capacity(Resource::Up(w), 100.0);
        }
        g.aggregate_cap = Some(200.0);
        let xs: Vec<_> =
            (0..4).map(|w| g.add(Node::transfer(w, true, 500.0))).collect();
        let c = g.add(Node::compute(0, 1.0));
        let out = execute(&g);
        // 4 transfers share 200 u/s aggregate -> 50 each -> 10 s
        for x in xs {
            assert!(close(out.finish[x], 10.0));
        }
        // the CPU job is not a transfer: full rate
        assert!(close(out.finish[c], 1.0));
    }

    #[test]
    fn base_latency_and_extra_lag_stack() {
        let mut g = FlowGraph::new();
        g.base_latency = 0.5;
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(1), 100.0);
        let a = g.add(Node::transfer(0, true, 100.0)); // ready 0.5, done 1.5
        let b = g.add(Node::transfer(1, false, 100.0).after(vec![a]));
        let out = execute(&g);
        assert!(close(out.finish[a], 1.5));
        // b starts at 1.5 + 0.5 latency, takes 1 s
        assert!(close(out.finish[b], 3.0));
    }

    #[test]
    fn zero_work_completes_at_ready() {
        let mut g = FlowGraph::new();
        g.base_latency = 0.25;
        let f = g.add(Node::transfer(0, true, 0.0).ready_at(1.0));
        let out = execute(&g);
        assert!(close(out.finish[f], 1.25));
    }

    #[test]
    fn worker_start_offsets_delay_whole_worker() {
        let mut g = FlowGraph::new();
        let a = g.add(Node::compute(0, 1.0));
        let b = g.add(Node::compute(1, 1.0));
        g.delay_worker(1, 2.0);
        let out = execute(&g);
        assert!(close(out.finish[a], 1.0));
        assert!(close(out.finish[b], 3.0));
        assert!(close(out.makespan, 3.0));
    }

    #[test]
    fn direct_transfer_occupies_both_ends() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(1), 50.0);
        let d = g.add(Node::direct(0, 1, 100.0));
        let out = execute(&g);
        // bound by the slower endpoint
        assert!(close(out.finish[d], 2.0));
    }

    #[test]
    fn deterministic_across_runs() {
        let build = || {
            let mut g = FlowGraph::new();
            g.set_capacity(Resource::Up(0), 70e6);
            g.set_capacity(Resource::Down(0), 70e6);
            let mut prev = None;
            for k in 0..32 {
                let deps = prev.map(|p| vec![p]).unwrap_or_default();
                let n = if k % 3 == 0 {
                    Node::transfer(0, k % 2 == 0, 1e6 + k as f64)
                } else {
                    Node::compute(0, 0.01 * (k + 1) as f64)
                };
                prev = Some(g.add(n.after(deps)));
            }
            g
        };
        let a = execute(&build());
        let b = execute(&build());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
