//! The discrete-event executor: one event loop for every simulation in
//! the repo (collective flow schedules, the pipeline DES, FlowSim).
//!
//! Semantics (a faithful generalization of the two engines it replaced):
//!
//! * a node becomes *ready* `delay` seconds after its last dependency
//!   finishes (roots: after its absolute `ready` time), but never before
//!   its worker's start offset;
//! * active nodes share resources **max-min fairly** by progressive
//!   filling, re-run whenever the active set changes; each resource is
//!   one constraint (its capacity over its active members) and the
//!   optional aggregate cap is one more constraint over all active
//!   transfers;
//! * time advances to the earliest of (a) the first completion at the
//!   current rates, (b) the next readiness instant — so rate changes are
//!   exact, not sampled;
//! * ties break deterministically: nodes are scanned, completed and
//!   resolved in id order, and constraints are assembled in first-seen
//!   order — identical inputs give bit-identical outputs on every run
//!   and platform.
//!
//! Two executors implement those semantics:
//!
//! * [`execute`] — the incremental event-driven engine. Nodes are
//!   partitioned once into *static components* (union-find over shared
//!   resources; the aggregate cap joins every transfer into one
//!   component). Rates are re-solved per component, only when that
//!   component's active membership changed, with lazy work settlement
//!   and an epoch-invalidated completion heap — so a graph of 10³–10⁴
//!   independent workers costs O(events · log events), not
//!   O(nodes · events). Simultaneous events are batched into one round.
//! * [`execute_full`] — the original whole-graph loop: full O(n) scan
//!   and full re-solve on every active-set change. Kept as the
//!   reference implementation; the equivalence tests and the
//!   `perf_hotpath` 1024-worker rows compare against it.
//!
//! The two engines agree to tolerance (not bit-for-bit: they settle
//! remaining work on different schedules, so float rounding differs in
//! the last ulps), and each is individually run-to-run deterministic.

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap};

use super::graph::{FlowGraph, OpKind};

/// Execution result: per-node finish times plus the makespan.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Finish instant of each node, indexed by [`NodeId`](super::NodeId).
    pub finish: Vec<f64>,
    /// Latest finish over all nodes (0.0 for an empty graph).
    pub makespan: f64,
}

/// Simulated instants are finite and non-NaN by construction, so
/// `total_cmp` gives the heap a real total order.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Tm(f64);

impl Eq for Tm {}

impl PartialOrd for Tm {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Tm {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

const EV_READY: u8 = 0;
const EV_DONE: u8 = 1;

/// Heap entry: `(instant, kind, node, epoch)`. Min-ordered via
/// `Reverse`; ties resolve by kind then node id, keeping pops
/// deterministic.
type Ev = Reverse<(Tm, u8, usize, u64)>;

/// Union-find with path halving; components are fixed once built, so no
/// ranks are needed.
struct Dsu(Vec<usize>);

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu((0..n).collect())
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.0[x] != x {
            self.0[x] = self.0[self.0[x]];
            x = self.0[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // root the larger id under the smaller: component ids then
            // enumerate in first-node order, independent of union order
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.0[hi] = lo;
        }
    }
}

/// One static resource-sharing component: the unit of incremental
/// re-solving. `rates` is parallel to `active`.
struct Comp {
    active: Vec<usize>,
    rates: Vec<f64>,
    /// Instant up to which members' remaining work has been settled at
    /// the current `rates`.
    settled: f64,
}

impl Comp {
    /// Burn members' remaining work forward to `t` at the current rates.
    /// Must run before any membership or rate change.
    fn settle(&mut self, t: f64, remaining: &mut [f64]) {
        let dt = t - self.settled;
        if dt > 0.0 {
            for (k, &i) in self.active.iter().enumerate() {
                remaining[i] = (remaining[i] - self.rates[k] * dt).max(0.0);
            }
        }
        self.settled = t;
    }
}

/// Run `graph` to completion of every node (incremental engine).
///
/// Panics on a deadlocked graph (a dependency cycle, which the builders
/// cannot produce, or a zero-capacity resource with pending work).
pub fn execute(graph: &FlowGraph) -> SimOutcome {
    let n = graph.nodes.len();
    if n == 0 {
        return SimOutcome { finish: Vec::new(), makespan: 0.0 };
    }

    // --- static components: nodes sharing any resource are co-solved;
    // the aggregate cap couples every transfer ---------------------------
    let mut dsu = Dsu::new(n);
    {
        let mut owner: std::collections::HashMap<super::Resource, usize> =
            std::collections::HashMap::new();
        let mut first_transfer: Option<usize> = None;
        for (i, node) in graph.nodes.iter().enumerate() {
            for &r in &node.resources {
                match owner.get(&r) {
                    Some(&o) => dsu.union(o, i),
                    None => {
                        owner.insert(r, i);
                    }
                }
            }
            if graph.aggregate_cap.is_some() && node.kind == OpKind::Transfer {
                match first_transfer {
                    Some(o) => dsu.union(o, i),
                    None => first_transfer = Some(i),
                }
            }
        }
    }
    let mut comp_of = vec![usize::MAX; n];
    let mut comps: Vec<Comp> = Vec::new();
    for i in 0..n {
        let root = dsu.find(i);
        if comp_of[root] == usize::MAX {
            comp_of[root] = comps.len();
            comps.push(Comp {
                active: Vec::new(),
                rates: Vec::new(),
                settled: 0.0,
            });
        }
        comp_of[i] = comp_of[root];
    }

    // --- per-node state -------------------------------------------------
    let mut remaining: Vec<f64> = graph.nodes.iter().map(|x| x.work).collect();
    let mut finish: Vec<Option<f64>> = vec![None; n];
    let mut deps_left: Vec<usize> =
        graph.nodes.iter().map(|x| x.deps.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in graph.nodes.iter().enumerate() {
        for &d in &node.deps {
            dependents[d].push(i);
        }
    }
    // epoch-invalidated completion events: only the entry whose epoch
    // matches the node's current epoch is live
    let mut epoch: Vec<u64> = vec![0; n];

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    for (i, node) in graph.nodes.iter().enumerate() {
        if node.deps.is_empty() {
            let rt = (node.ready + node.delay).max(graph.worker_start(node.worker));
            heap.push(Reverse((Tm(rt), EV_READY, i, 0)));
        }
    }

    let mut t = 0.0f64;
    let mut done = 0usize;
    let mut makespan = 0.0f64;
    // components whose membership changed this round, in id order
    let mut dirty: BTreeSet<usize> = BTreeSet::new();

    while done < n {
        // --- next valid event -------------------------------------------
        let Some(&Reverse((Tm(te), _, _, _))) = heap.peek() else {
            let stalled = comps.iter().any(|c| !c.active.is_empty());
            assert!(
                !stalled,
                "simcore: no progress possible at t={t} ({} unfinished)",
                n - done
            );
            panic!("simcore: deadlock with {} nodes unfinished", n - done);
        };
        t = te.max(t);

        // --- drain the simultaneous batch (one event round) -------------
        let mut completions: Vec<usize> = Vec::new();
        let mut activations: Vec<usize> = Vec::new();
        while let Some(&Reverse((Tm(et), kind, i, ep))) = heap.peek() {
            if et > t + 1e-12 {
                break;
            }
            heap.pop();
            if finish[i].is_some() {
                continue; // stale: already finished
            }
            match kind {
                EV_READY => activations.push(i),
                _ => {
                    if ep == epoch[i] {
                        completions.push(i);
                    } // else stale: rates changed since it was queued
                }
            }
        }
        completions.sort_unstable();
        activations.sort_unstable();

        // --- fixpoint at instant t: completions unlock dependents whose
        // readiness (and possibly zero-work completion) lands at t -------
        loop {
            let mut newly_done: Vec<usize> = Vec::new();

            for &i in &completions {
                if finish[i].is_some() {
                    continue;
                }
                let c = comp_of[i];
                comps[c].settle(t, &mut remaining);
                // batch: complete every settled member of the component
                // within the scale-aware snap (work is bytes for
                // transfers, seconds for compute — an absolute epsilon
                // would bind differently per class)
                let members: Vec<usize> = comps[c].active.clone();
                for m in members {
                    if finish[m].is_none()
                        && remaining[m] <= 1e-9 * graph.nodes[m].work.max(1.0)
                    {
                        remaining[m] = 0.0;
                        finish[m] = Some(t);
                        makespan = makespan.max(t);
                        done += 1;
                        newly_done.push(m);
                        let pos = comps[c]
                            .active
                            .iter()
                            .position(|&x| x == m)
                            .expect("completing a non-member");
                        comps[c].active.remove(pos);
                        comps[c].rates.remove(pos);
                    }
                }
                dirty.insert(c);
            }
            completions.clear();

            // activate ready nodes (zero-work completes the instant it is
            // ready; real work joins its component for the re-solve)
            for &i in &activations {
                if finish[i].is_some() {
                    continue;
                }
                if remaining[i] <= 1e-12 {
                    remaining[i] = 0.0;
                    finish[i] = Some(t);
                    makespan = makespan.max(t);
                    done += 1;
                    newly_done.push(i);
                } else {
                    let c = comp_of[i];
                    comps[c].settle(t, &mut remaining);
                    comps[c].active.push(i);
                    comps[c].active.sort_unstable();
                    let pos = comps[c]
                        .active
                        .iter()
                        .position(|&x| x == i)
                        .expect("just inserted");
                    comps[c].rates.insert(pos, 0.0);
                    dirty.insert(c);
                }
            }
            activations.clear();

            if newly_done.is_empty() {
                break;
            }
            newly_done.sort_unstable();

            // resolve dependents in id order; same-instant readiness
            // loops back as this round's activations
            for &d in &newly_done {
                for &i in &dependents[d] {
                    deps_left[i] -= 1;
                    if deps_left[i] == 0 {
                        let node = &graph.nodes[i];
                        let latest = node
                            .deps
                            .iter()
                            .map(|&x| finish[x].expect("dep not finished"))
                            .fold(0.0f64, f64::max);
                        let rt = (latest + node.delay)
                            .max(graph.worker_start(node.worker));
                        if rt <= t + 1e-12 {
                            activations.push(i);
                        } else {
                            heap.push(Reverse((Tm(rt), EV_READY, i, 0)));
                        }
                    }
                }
            }
            if activations.is_empty() {
                break;
            }
            activations.sort_unstable();
        }

        // --- re-solve only the components whose membership changed ------
        for &c in &dirty {
            let comp = &mut comps[c];
            debug_assert!(comp.settled <= t + 1e-12);
            comp.settled = t;
            comp.rates = allocate_rates(graph, &comp.active);
            for (k, &i) in comp.active.iter().enumerate() {
                if comp.rates[k] > 1e-12 {
                    epoch[i] += 1;
                    let tf = t + remaining[i] / comp.rates[k];
                    heap.push(Reverse((Tm(tf), EV_DONE, i, epoch[i])));
                } else {
                    // stalled member: invalidate any queued completion so
                    // a later re-solve is its only way forward
                    epoch[i] += 1;
                }
            }
        }
        dirty.clear();
    }

    SimOutcome {
        finish: finish.into_iter().map(|f| f.unwrap_or(0.0)).collect(),
        makespan,
    }
}

/// Run `graph` to completion with the original whole-graph loop: a full
/// O(n) active-set scan and a full-graph rate re-solve on every change.
///
/// Semantically equivalent to [`execute`] (to float tolerance) and
/// individually deterministic; kept as the reference oracle for the
/// equivalence suite and as the "pre-refactor" baseline the
/// `perf_hotpath`/`planner_search` 1024-worker rows measure against.
pub fn execute_full(graph: &FlowGraph) -> SimOutcome {
    let n = graph.nodes.len();
    let mut remaining: Vec<f64> = graph.nodes.iter().map(|x| x.work).collect();
    let mut finish: Vec<Option<f64>> = vec![None; n];
    // resolved readiness: known immediately for roots, filled in as
    // dependencies complete
    let mut ready: Vec<Option<f64>> = (0..n)
        .map(|i| {
            let node = &graph.nodes[i];
            node.deps.is_empty().then(|| {
                (node.ready + node.delay).max(graph.worker_start(node.worker))
            })
        })
        .collect();

    let mut t = 0.0f64;
    let mut done = 0usize;
    let mut makespan = 0.0f64;

    while done < n {
        // active set, in id order (deterministic tie-breaking)
        let active: Vec<usize> = (0..n)
            .filter(|&i| {
                finish[i].is_none()
                    && ready[i].map(|r| r <= t + 1e-12).unwrap_or(false)
            })
            .collect();

        // zero-work nodes complete the instant they are ready
        let mut completed: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| remaining[i] <= 1e-12)
            .collect();

        if completed.is_empty() && !active.is_empty() {
            let rates = allocate_rates(graph, &active);

            // earliest completion at these rates
            let mut dt = f64::INFINITY;
            for (k, &i) in active.iter().enumerate() {
                if rates[k] > 1e-12 {
                    dt = dt.min(remaining[i] / rates[k]);
                }
            }
            // ... capped by the next readiness instant
            let next_ready = (0..n)
                .filter(|&i| finish[i].is_none())
                .filter_map(|i| ready[i])
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            if next_ready.is_finite() {
                dt = dt.min(next_ready - t);
            }
            assert!(
                dt.is_finite() && dt > 0.0,
                "simcore: no progress possible at t={t} ({} unfinished)",
                n - done
            );

            for (k, &i) in active.iter().enumerate() {
                remaining[i] -= rates[k] * dt;
            }
            t += dt;

            completed = active
                .iter()
                .copied()
                .filter(|&i| {
                    // scale-aware completion snap: work is bytes for
                    // transfers and seconds for compute, so an absolute
                    // epsilon would bind differently per class
                    remaining[i] <= 1e-9 * graph.nodes[i].work.max(1.0)
                })
                .collect();
        } else if completed.is_empty() {
            // nothing running: jump to the next readiness instant
            let next_ready = (0..n)
                .filter(|&i| finish[i].is_none())
                .filter_map(|i| ready[i])
                .filter(|&r| r > t + 1e-12)
                .fold(f64::INFINITY, f64::min);
            assert!(
                next_ready.is_finite(),
                "simcore: deadlock with {} nodes unfinished",
                n - done
            );
            t = next_ready;
            continue;
        }

        for &i in &completed {
            remaining[i] = 0.0;
            finish[i] = Some(t);
            makespan = makespan.max(t);
        }
        done += completed.len();

        // resolve newly-ready dependents (id order)
        for i in 0..n {
            if ready[i].is_some() || finish[i].is_some() {
                continue;
            }
            let node = &graph.nodes[i];
            let mut all = true;
            let mut latest: f64 = 0.0;
            for &d in &node.deps {
                match finish[d] {
                    Some(f) => latest = latest.max(f),
                    None => {
                        all = false;
                        break;
                    }
                }
            }
            if all {
                ready[i] = Some(
                    (latest + node.delay).max(graph.worker_start(node.worker)),
                );
            }
        }
    }

    SimOutcome {
        finish: finish.into_iter().map(|f| f.unwrap_or(0.0)).collect(),
        makespan,
    }
}

/// Max-min fair rates for the `active` node set by progressive filling
/// over the resource constraints (plus the aggregate transfer cap).
///
/// Public because it is THE allocator: both engines call it every time
/// an active set changes ([`execute`] hands it one component's members,
/// [`execute_full`] the whole active set — identical semantics because
/// resources never span components), and `platform::network::max_min_rates`
/// (the historical entry point the property tests exercise) is an
/// adapter over it — there is exactly one max-min implementation in
/// the repo.
pub fn allocate_rates(graph: &FlowGraph, active: &[usize]) -> Vec<f64> {
    let na = active.len();
    let mut rates = vec![0.0f64; na];
    if na == 0 {
        return rates;
    }

    // constraints in deterministic first-seen order; members index into
    // `active`/`rates`
    let mut cons: Vec<(f64, Vec<usize>)> = Vec::new();
    let mut rmap: std::collections::HashMap<super::Resource, usize> =
        std::collections::HashMap::new();
    for (k, &i) in active.iter().enumerate() {
        for &r in &graph.nodes[i].resources {
            let ci = *rmap.entry(r).or_insert_with(|| {
                cons.push((graph.capacity(r), Vec::new()));
                cons.len() - 1
            });
            cons[ci].1.push(k);
        }
    }
    if let Some(cap) = graph.aggregate_cap {
        let members: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|&(_, &i)| graph.nodes[i].kind == OpKind::Transfer)
            .map(|(k, _)| k)
            .collect();
        if !members.is_empty() {
            cons.push((cap, members));
        }
    }

    let mut alive = vec![true; na];
    let mut used = vec![0.0f64; cons.len()];
    let mut n_alive = na;

    while n_alive > 0 {
        // bottleneck: smallest equal increment saturating a constraint
        let mut best_inc = f64::INFINITY;
        for (ci, (cap, members)) in cons.iter().enumerate() {
            let k = members.iter().filter(|&&m| alive[m]).count();
            if k == 0 {
                continue;
            }
            let inc = (cap - used[ci]) / k as f64;
            if inc < best_inc {
                best_inc = inc;
            }
        }
        if !best_inc.is_finite() {
            break; // node with no constraint: cannot happen by construction
        }
        let best_inc = best_inc.max(0.0);

        for (m, r) in rates.iter_mut().enumerate() {
            if alive[m] {
                *r += best_inc;
            }
        }
        for (ci, (_, members)) in cons.iter().enumerate() {
            let k = members.iter().filter(|&&m| alive[m]).count();
            used[ci] += best_inc * k as f64;
        }

        // freeze members of saturated constraints (scale-aware epsilon:
        // capacities span 1.0 CPU units to 1e9 byte/s links)
        let mut froze = false;
        for (ci, (cap, members)) in cons.iter().enumerate() {
            if used[ci] >= cap - 1e-9 * cap.max(1.0) {
                for &m in members {
                    if alive[m] {
                        alive[m] = false;
                        n_alive -= 1;
                        froze = true;
                    }
                }
            }
        }
        if !froze {
            break; // numerical safety, mirrors the old allocator
        }
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::super::{FlowGraph, Node, Resource};
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * b.abs().max(1.0)
    }

    #[test]
    fn serial_chain_sums_work() {
        let mut g = FlowGraph::new();
        let a = g.add(Node::compute(0, 2.0));
        let b = g.add(Node::compute(0, 3.0).after(vec![a]));
        let out = execute(&g);
        assert!(close(out.finish[a], 2.0));
        assert!(close(out.finish[b], 5.0));
        assert!(close(out.makespan, 5.0));
    }

    #[test]
    fn shared_resource_is_fair() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        let a = g.add(Node::transfer(0, true, 500.0));
        let b = g.add(Node::transfer(0, true, 500.0));
        let out = execute(&g);
        assert!(close(out.finish[a], 10.0));
        assert!(close(out.finish[b], 10.0));
    }

    #[test]
    fn duplex_links_are_independent() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(0), 100.0);
        let up = g.add(Node::transfer(0, true, 1000.0));
        let down = g.add(Node::transfer(0, false, 1000.0));
        let out = execute(&g);
        assert!(close(out.finish[up], 10.0));
        assert!(close(out.finish[down], 10.0));
    }

    #[test]
    fn aggregate_cap_spans_transfers_but_not_compute() {
        let mut g = FlowGraph::new();
        for w in 0..4 {
            g.set_capacity(Resource::Up(w), 100.0);
        }
        g.aggregate_cap = Some(200.0);
        let xs: Vec<_> =
            (0..4).map(|w| g.add(Node::transfer(w, true, 500.0))).collect();
        let c = g.add(Node::compute(0, 1.0));
        let out = execute(&g);
        // 4 transfers share 200 u/s aggregate -> 50 each -> 10 s
        for x in xs {
            assert!(close(out.finish[x], 10.0));
        }
        // the CPU job is not a transfer: full rate
        assert!(close(out.finish[c], 1.0));
    }

    #[test]
    fn base_latency_and_extra_lag_stack() {
        let mut g = FlowGraph::new();
        g.base_latency = 0.5;
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(1), 100.0);
        let a = g.add(Node::transfer(0, true, 100.0)); // ready 0.5, done 1.5
        let b = g.add(Node::transfer(1, false, 100.0).after(vec![a]));
        let out = execute(&g);
        assert!(close(out.finish[a], 1.5));
        // b starts at 1.5 + 0.5 latency, takes 1 s
        assert!(close(out.finish[b], 3.0));
    }

    #[test]
    fn zero_work_completes_at_ready() {
        let mut g = FlowGraph::new();
        g.base_latency = 0.25;
        let f = g.add(Node::transfer(0, true, 0.0).ready_at(1.0));
        let out = execute(&g);
        assert!(close(out.finish[f], 1.25));
    }

    #[test]
    fn worker_start_offsets_delay_whole_worker() {
        let mut g = FlowGraph::new();
        let a = g.add(Node::compute(0, 1.0));
        let b = g.add(Node::compute(1, 1.0));
        g.delay_worker(1, 2.0);
        let out = execute(&g);
        assert!(close(out.finish[a], 1.0));
        assert!(close(out.finish[b], 3.0));
        assert!(close(out.makespan, 3.0));
    }

    #[test]
    fn direct_transfer_occupies_both_ends() {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 100.0);
        g.set_capacity(Resource::Down(1), 50.0);
        let d = g.add(Node::direct(0, 1, 100.0));
        let out = execute(&g);
        // bound by the slower endpoint
        assert!(close(out.finish[d], 2.0));
    }

    fn chain_graph() -> FlowGraph {
        let mut g = FlowGraph::new();
        g.set_capacity(Resource::Up(0), 70e6);
        g.set_capacity(Resource::Down(0), 70e6);
        let mut prev = None;
        for k in 0..32 {
            let deps = prev.map(|p| vec![p]).unwrap_or_default();
            let n = if k % 3 == 0 {
                Node::transfer(0, k % 2 == 0, 1e6 + k as f64)
            } else {
                Node::compute(0, 0.01 * (k + 1) as f64)
            };
            prev = Some(g.add(n.after(deps)));
        }
        g
    }

    #[test]
    fn deterministic_across_runs() {
        let a = execute(&chain_graph());
        let b = execute(&chain_graph());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn full_engine_deterministic_across_runs() {
        let a = execute_full(&chain_graph());
        let b = execute_full(&chain_graph());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// A deliberately nasty graph: many workers, cross-worker deps,
    /// zero-work barriers, lags, start offsets and an aggregate cap that
    /// fuses every transfer into one big component.
    fn layered_graph(workers: usize, agg: Option<f64>) -> FlowGraph {
        let mut g = FlowGraph::new();
        g.base_latency = 0.01;
        g.aggregate_cap = agg;
        for w in 0..workers {
            g.set_capacity(Resource::Up(w), 50.0 + (w % 7) as f64 * 10.0);
            g.set_capacity(Resource::Down(w), 80.0 + (w % 5) as f64 * 5.0);
        }
        let mut heads = Vec::with_capacity(workers);
        for w in 0..workers {
            if w % 11 == 0 {
                g.delay_worker(w, 0.5 + (w % 3) as f64 * 0.25);
            }
            let c1 = g.add(Node::compute(w, 0.2 + (w % 4) as f64 * 0.05));
            let up = g.add(
                Node::transfer(w, true, 100.0 + (w % 9) as f64 * 20.0)
                    .after(vec![c1]),
            );
            let down = g.add(
                Node::transfer(w, false, 150.0 + (w % 6) as f64 * 10.0)
                    .after(vec![up])
                    .lag(0.02),
            );
            let c2 = g.add(Node::compute(w, 0.1).after(vec![down]));
            heads.push(c2);
        }
        // zero-work barrier joining neighbours, then a second wave
        for w in 0..workers {
            let peer = heads[(w + 1) % workers];
            let bar = g.add(Node::fixed(w, 0.0).after(vec![heads[w], peer]));
            let up2 = g.add(Node::transfer(w, true, 60.0).after(vec![bar]));
            g.add(Node::compute(w, 0.05).after(vec![up2]));
        }
        g
    }

    #[test]
    fn incremental_matches_full_without_aggregate_cap() {
        let g = layered_graph(24, None);
        let a = execute(&g);
        let b = execute_full(&g);
        assert_eq!(a.finish.len(), b.finish.len());
        assert!(close(a.makespan, b.makespan));
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert!(close(*x, *y), "finish diverged: {x} vs {y}");
        }
    }

    #[test]
    fn incremental_matches_full_with_aggregate_cap() {
        let g = layered_graph(16, Some(400.0));
        let a = execute(&g);
        let b = execute_full(&g);
        assert!(close(a.makespan, b.makespan));
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert!(close(*x, *y), "finish diverged: {x} vs {y}");
        }
    }

    #[test]
    fn incremental_is_deterministic_on_large_graphs() {
        let a = execute(&layered_graph(64, None));
        let b = execute(&layered_graph(64, None));
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
