//! The declarative flow graph executed by [`engine`](super::engine).
//!
//! A [`FlowGraph`] is a DAG of [`Node`]s, each consuming one or two
//! [`Resource`]s while it runs. Producers — the collective emitters in
//! [`collective::sim`](crate::collective::sim), the pipeline translator
//! in [`pipeline::simulate`](crate::pipeline::simulate) — only *describe*
//! work; all timing semantics (max-min fair sharing, dependency
//! resolution, storage latency, deterministic tie-breaking) live in the
//! engine. Chunked and unchunked collectives are the same graph at
//! different granularity; pipeline and sync simulation compose in one
//! timeline because they are nodes of the same vocabulary.
//!
//! Work units are whatever the occupied resources' capacities are
//! expressed in: the collective emitters use bytes on byte/s links
//! (capacities from a [`BandwidthModel`]), the pipeline translator
//! pre-divides transfers by effective bandwidth and runs everything on
//! unit-capacity resources — both are first-class citizens of the same
//! engine.

use std::collections::HashMap;

use crate::platform::network::BandwidthModel;

/// Index of a node within its graph.
pub type NodeId = usize;

/// What a node occupies while running. Capacities default to 1.0
/// work-unit/s and can be overridden per resource
/// ([`FlowGraph::set_capacity`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    /// Worker CPU.
    Cpu(usize),
    /// Worker uplink (toward storage).
    Up(usize),
    /// Worker downlink (from storage).
    Down(usize),
    /// A dedicated virtual channel (closed-form sync jobs run here so
    /// they serialize per worker without contending with real links).
    Virtual(usize),
}

/// Node class — scenarios and the aggregate storage cap select by it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Computation (work in seconds on a CPU resource).
    Compute,
    /// A storage/network transfer (subject to the aggregate cap and to
    /// bandwidth-jitter scenarios).
    Transfer,
    /// A fixed-duration occupancy on a virtual channel (e.g. a
    /// closed-form synchronization term).
    Fixed,
}

/// One unit of simulated work.
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: OpKind,
    /// Owning worker (scenario targeting; every resource of the node
    /// belongs to it except the destination end of a direct transfer).
    pub worker: usize,
    /// Resource endpoints occupied while running (1, or 2 for direct
    /// worker→worker transfers).
    pub resources: Vec<Resource>,
    /// Work amount in resource units (bytes or seconds).
    pub work: f64,
    pub deps: Vec<NodeId>,
    /// Absolute earliest start — only meaningful for root nodes
    /// (dependency nodes start after their last dependency).
    pub ready: f64,
    /// Start lag applied once the node becomes ready (per-operation
    /// storage latency and any extra delay; the graph's base latency is
    /// folded in by [`FlowGraph::add`]).
    pub delay: f64,
}

impl Node {
    fn new(kind: OpKind, worker: usize, resources: Vec<Resource>, work: f64) -> Self {
        Self {
            kind,
            worker,
            resources,
            work: work.max(0.0),
            deps: Vec::new(),
            ready: 0.0,
            delay: 0.0,
        }
    }

    /// Transfer on `worker`'s uplink (`up == true`) or downlink.
    pub fn transfer(worker: usize, up: bool, work: f64) -> Self {
        let r = if up { Resource::Up(worker) } else { Resource::Down(worker) };
        Self::new(OpKind::Transfer, worker, vec![r], work)
    }

    /// Direct transfer occupying `src`'s uplink AND `dst`'s downlink
    /// (the HybridPS worker↔VM path).
    pub fn direct(src: usize, dst: usize, work: f64) -> Self {
        Self::new(
            OpKind::Transfer,
            src,
            vec![Resource::Up(src), Resource::Down(dst)],
            work,
        )
    }

    /// Computation on `worker`'s CPU.
    pub fn compute(worker: usize, work: f64) -> Self {
        Self::new(OpKind::Compute, worker, vec![Resource::Cpu(worker)], work)
    }

    /// Fixed-duration job on `worker`'s dedicated virtual channel.
    pub fn fixed(worker: usize, work: f64) -> Self {
        Self::new(OpKind::Fixed, worker, vec![Resource::Virtual(worker)], work)
    }

    /// Gate on `deps` (start after the last one finishes).
    pub fn after(mut self, deps: Vec<NodeId>) -> Self {
        self.deps = deps;
        self
    }

    /// Absolute earliest start for a root node.
    pub fn ready_at(mut self, t: f64) -> Self {
        self.ready = t;
        self
    }

    /// Extra start lag on top of the graph's base latency.
    pub fn lag(mut self, extra: f64) -> Self {
        self.delay += extra;
        self
    }
}

/// A complete simulation input: nodes + resource capacities + the
/// optional storage-side aggregate cap + per-worker start offsets
/// (cold-start scenarios).
#[derive(Debug, Clone, Default)]
pub struct FlowGraph {
    pub nodes: Vec<Node>,
    caps: HashMap<Resource, f64>,
    /// Aggregate cap across all concurrently-running `Transfer` nodes
    /// (the storage NIC of Alibaba OSS, §5.7).
    pub aggregate_cap: Option<f64>,
    /// Added to every node's start lag at [`FlowGraph::add`] time — the
    /// per-operation storage latency of the bandwidth model.
    pub base_latency: f64,
    worker_start: HashMap<usize, f64>,
}

impl FlowGraph {
    /// Empty graph: unit capacities, no aggregate cap, zero latency.
    pub fn new() -> Self {
        Self::default()
    }

    /// Graph whose link capacities, aggregate cap and per-operation
    /// latency come from a [`BandwidthModel`] — the collective emitters'
    /// substrate (transfers in bytes).
    pub fn with_network(model: &BandwidthModel) -> Self {
        let mut g = Self::new();
        for w in 0..model.n_workers() {
            g.caps.insert(Resource::Up(w), model.up_bps[w]);
            g.caps.insert(Resource::Down(w), model.down_bps[w]);
        }
        g.aggregate_cap = model.aggregate_cap_bps;
        g.base_latency = model.latency_s;
        g
    }

    /// Capacity of `r` in work-units/s (default 1.0).
    pub fn capacity(&self, r: Resource) -> f64 {
        self.caps.get(&r).copied().unwrap_or(1.0)
    }

    pub fn set_capacity(&mut self, r: Resource, cap: f64) {
        self.caps.insert(r, cap);
    }

    /// Append a node; the graph's base latency folds into its start lag.
    pub fn add(&mut self, mut node: Node) -> NodeId {
        debug_assert!(
            node.deps.iter().all(|&d| d < self.nodes.len()),
            "node depends on a node not yet added"
        );
        node.delay += self.base_latency;
        let id = self.nodes.len();
        self.nodes.push(node);
        id
    }

    /// Delay every node of `worker` to start no earlier than the
    /// accumulated offset (cold-start scenarios).
    pub fn delay_worker(&mut self, worker: usize, delay: f64) {
        *self.worker_start.entry(worker).or_insert(0.0) += delay.max(0.0);
    }

    /// Earliest instant any node of `worker` may start.
    pub fn worker_start(&self, worker: usize) -> f64 {
        self.worker_start.get(&worker).copied().unwrap_or(0.0)
    }

    /// 1 + the largest worker index any node names (0 for empty graphs).
    pub fn n_workers(&self) -> usize {
        self.nodes
            .iter()
            .flat_map(|n| {
                n.resources.iter().map(|r| match *r {
                    Resource::Cpu(w)
                    | Resource::Up(w)
                    | Resource::Down(w)
                    | Resource::Virtual(w) => w,
                })
                .chain(std::iter::once(n.worker))
            })
            .max()
            .map_or(0, |w| w + 1)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}
