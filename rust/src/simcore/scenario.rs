//! Pluggable serverless scenarios: seeded perturbations applied to a
//! [`FlowGraph`] before execution, turning the simulator from a pure
//! validation tool into a scenario lab.
//!
//! Related serverless-training studies show the real environment is
//! dominated by effects a deterministic model cannot express — container
//! cold starts, stragglers and bandwidth jitter ("Towards Demystifying
//! Serverless Machine Learning Training"; SMLT's adaptive scaling is
//! motivated by exactly this variance). Each scenario perturbs one of
//! those axes, deterministically from a `u64` seed (xoshiro256** via
//! [`util::rng`](crate::util::rng)): same seed + scenario ⇒ bit-identical
//! simulation, different seeds ⇒ different draws. Every draw happens in
//! worker- or node-id order, never from iteration over unordered
//! containers, which is what makes replay exact.

use crate::util::rng::Rng;

use super::graph::{FlowGraph, OpKind};

/// A named, seeded perturbation model.
///
/// The wire names (config `"scenario"` key, `--scenario` flag) are
/// `deterministic`, `cold-start`, `straggler` and `bandwidth-jitter`;
/// [`ScenarioModel::parse`] is the inverse of [`ScenarioModel::as_str`].
/// Parameters are fixed per name so a name round-trips losslessly
/// through configs and plan artifacts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioModel {
    /// No perturbation; the seed is ignored (and no RNG is consumed).
    Deterministic,
    /// Each worker's function instance boots `Exp(1/mean_s)` seconds
    /// late — every node of that worker starts no earlier.
    ColdStart { mean_s: f64 },
    /// Per-worker compute slowdown: with probability `prob` a worker is
    /// a straggler (compute stretched by up to `slowdown`×); every
    /// worker also gets a small continuous background factor so that
    /// different seeds always produce different timelines.
    Straggler { prob: f64, slowdown: f64 },
    /// Lognormal bandwidth variation: every transfer (and closed-form
    /// sync occupancy) is stretched by `exp(σ·N(0,1))`, compute by the
    /// paper-calibrated σ/3 — the Table 3 "measured" noise.
    BandwidthJitter { sigma: f64 },
    /// Transient storage failures: each transfer independently (with
    /// probability `prob`, drawn in node-id order) suffers one dropped
    /// `get_blocking` attempt and pays `timeout_s` of dead waiting
    /// before its retry goes through. The runtime analogue injects the
    /// drop into the real trainer's store handle and the retry layer
    /// absorbs it (see [`Injector`](crate::scenario::Injector)).
    FlakyNetwork { prob: f64, timeout_s: f64 },
    /// Time-varying: store bandwidth degrades over the virtual run —
    /// step `t`'s multiplier is `max(floor, (1-rate)^t)` plus a small
    /// seeded per-(tenant, worker, step) wobble
    /// ([`Injector::step_bandwidth_mult`](crate::scenario::Injector::step_bandwidth_mult)).
    /// A single-iteration graph has no step axis, so [`apply`] projects
    /// the fixed probe step [`DECAY_PROBE_STEP`] onto every transfer
    /// (with the per-worker wobble drawn in worker order).
    ///
    /// [`apply`]: ScenarioModel::apply
    BandwidthDecay { rate: f64, floor: f64 },
    /// Time-varying: a correlated cold-start storm. One seeded window
    /// of steps (drawn from the seed alone, so every tenant of a fleet
    /// sees the *same* window) during which each (tenant, worker, step)
    /// draws `Exp(1/mean_s)` seconds of extra start latency. The graph
    /// projection treats the whole iteration as inside the window and
    /// delays every worker like `cold-start` does, from this lens's own
    /// tagged stream.
    ColdStartStorm { mean_s: f64 },
    /// Time-varying: spot-style capacity revocation. Each (tenant,
    /// worker, step) is revoked with probability `prob`
    /// ([`Injector::step_revoked`](crate::scenario::Injector::step_revoked));
    /// a revoked tenant loses its workers and re-queues for admission.
    /// The graph projection delays each hit worker by a seeded restart
    /// penalty, drawn in worker order.
    SpotRevocation { prob: f64 },
}

/// Stream tags: each scenario draws from `Rng::new(seed ^ TAG)`. Shared
/// with the runtime [`Injector`](crate::scenario::Injector) — "sim and
/// real draw from identical streams" is only true while there is exactly
/// one definition of these.
pub const COLD_START_TAG: u64 = 0xC01D_57A7;
pub const STRAGGLER_TAG: u64 = 0x57A6_61E6;
pub const BANDWIDTH_JITTER_TAG: u64 = 0xBA2D_317E;
pub const FLAKY_NETWORK_TAG: u64 = 0xF1A2_4E71;
pub const BANDWIDTH_DECAY_TAG: u64 = 0xDECA_BA2D;
pub const COLD_START_STORM_TAG: u64 = 0x5702_C01D;
pub const SPOT_REVOCATION_TAG: u64 = 0x5B07_4EF0;

/// The step the `bandwidth-decay` graph projection probes: a
/// single-iteration simulation has no step axis, so [`ScenarioModel::
/// apply`] evaluates the decay curve at this fixed virtual step (chosen
/// mid-run for the default 20-step training config).
pub const DECAY_PROBE_STEP: usize = 10;

/// The `bandwidth-decay` step multiplier every consumer shares: the
/// deterministic decay curve `max(floor, (1-rate)^step)` — the seeded
/// per-(tenant, worker, step) wobble lives in the injector, on top of
/// this.
pub fn decay_curve(rate: f64, floor: f64, step: usize) -> f64 {
    (1.0 - rate).powi(step as i32).max(floor.clamp(0.0, 1.0))
}

/// The cold-start scenario's per-worker start delays, in worker-id
/// order — the one stream both the simulator's graph perturbation and
/// the injector's generation-0 charges read.
pub fn cold_start_delays(seed: u64, mean_s: f64, n_workers: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ COLD_START_TAG);
    (0..n_workers).map(|_| rng.exponential(1.0 / mean_s)).collect()
}

/// The straggler scenario's per-worker compute factors, in worker-id
/// order. Both branches' uniforms are drawn unconditionally so the
/// stream consumed per worker is fixed; every worker gets a small
/// continuous background factor so distinct seeds always produce
/// distinct timelines.
pub fn straggler_factors(
    seed: u64,
    prob: f64,
    slowdown: f64,
    n_workers: usize,
) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ STRAGGLER_TAG);
    (0..n_workers)
        .map(|_| {
            let hit = rng.chance(prob);
            let heavy = rng.uniform(1.5, slowdown.max(1.5));
            let background = rng.uniform(1.0, 1.05);
            if hit {
                heavy
            } else {
                background
            }
        })
        .collect()
}

impl ScenarioModel {
    /// Stable wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ScenarioModel::Deterministic => "deterministic",
            ScenarioModel::ColdStart { .. } => "cold-start",
            ScenarioModel::Straggler { .. } => "straggler",
            ScenarioModel::BandwidthJitter { .. } => "bandwidth-jitter",
            ScenarioModel::FlakyNetwork { .. } => "flaky-network",
            ScenarioModel::BandwidthDecay { .. } => "bandwidth-decay",
            ScenarioModel::ColdStartStorm { .. } => "cold-start-storm",
            ScenarioModel::SpotRevocation { .. } => "spot-revocation",
        }
    }

    /// Parse a wire name into the scenario with its canonical
    /// parameters. Inverse of [`ScenarioModel::as_str`].
    pub fn parse(s: &str) -> Option<ScenarioModel> {
        match s {
            "deterministic" => Some(ScenarioModel::Deterministic),
            "cold-start" => Some(ScenarioModel::ColdStart { mean_s: 2.0 }),
            "straggler" => {
                Some(ScenarioModel::Straggler { prob: 0.2, slowdown: 2.5 })
            }
            "bandwidth-jitter" => {
                Some(ScenarioModel::BandwidthJitter { sigma: 0.15 })
            }
            "flaky-network" => {
                Some(ScenarioModel::FlakyNetwork { prob: 0.15, timeout_s: 0.5 })
            }
            "bandwidth-decay" => {
                Some(ScenarioModel::BandwidthDecay { rate: 0.02, floor: 0.3 })
            }
            "cold-start-storm" => {
                Some(ScenarioModel::ColdStartStorm { mean_s: 2.0 })
            }
            "spot-revocation" => {
                Some(ScenarioModel::SpotRevocation { prob: 0.08 })
            }
            _ => None,
        }
    }

    /// Every accepted wire name (error messages, CLI help).
    pub const NAMES: [&'static str; 8] = [
        "deterministic",
        "cold-start",
        "straggler",
        "bandwidth-jitter",
        "flaky-network",
        "bandwidth-decay",
        "cold-start-storm",
        "spot-revocation",
    ];

    pub fn is_deterministic(&self) -> bool {
        matches!(self, ScenarioModel::Deterministic)
    }

    /// Perturb `graph` in place, deterministically from `seed`.
    pub fn apply(&self, graph: &mut FlowGraph, seed: u64) {
        match *self {
            ScenarioModel::Deterministic => {}
            ScenarioModel::ColdStart { mean_s } => {
                let delays = cold_start_delays(seed, mean_s, graph.n_workers());
                for (w, d) in delays.iter().enumerate() {
                    graph.delay_worker(w, *d);
                }
            }
            ScenarioModel::Straggler { prob, slowdown } => {
                let factors =
                    straggler_factors(seed, prob, slowdown, graph.n_workers());
                for node in &mut graph.nodes {
                    if node.kind == OpKind::Compute {
                        node.work *= factors[node.worker];
                    }
                }
            }
            ScenarioModel::BandwidthJitter { sigma } => {
                let mut rng = Rng::new(seed ^ BANDWIDTH_JITTER_TAG);
                for node in &mut graph.nodes {
                    let sg = match node.kind {
                        OpKind::Compute => sigma / 3.0,
                        OpKind::Transfer | OpKind::Fixed => sigma,
                    };
                    // lognormal factor around 1 (a bandwidth dip makes
                    // the transfer longer)
                    node.work *= (sg * rng.normal()).exp();
                }
            }
            ScenarioModel::FlakyNetwork { prob, timeout_s } => {
                // one draw per transfer node, in node-id order; a hit
                // delays the op by the dead attempt's timeout (the
                // retry then moves the same bytes)
                let mut rng = Rng::new(seed ^ FLAKY_NETWORK_TAG);
                for node in &mut graph.nodes {
                    if node.kind == OpKind::Transfer && rng.chance(prob) {
                        node.delay += timeout_s;
                    }
                }
            }
            ScenarioModel::BandwidthDecay { rate, floor } => {
                // single-iteration projection at the fixed probe step:
                // one per-worker wobble draw in worker-id order, then
                // every transfer of that worker is stretched by the
                // reciprocal of its decayed bandwidth
                let mut rng = Rng::new(seed ^ BANDWIDTH_DECAY_TAG);
                let base = decay_curve(rate, floor, DECAY_PROBE_STEP);
                let mults: Vec<f64> = (0..graph.n_workers())
                    .map(|_| base * rng.uniform(0.97, 1.0))
                    .collect();
                for node in &mut graph.nodes {
                    if node.kind == OpKind::Transfer {
                        node.work /= mults[node.worker].max(1e-9);
                    }
                }
            }
            ScenarioModel::ColdStartStorm { mean_s } => {
                // the whole projected iteration sits inside the storm
                // window: every worker boots late, from this lens's own
                // tagged stream (composes with plain cold-start)
                let mut rng = Rng::new(seed ^ COLD_START_STORM_TAG);
                for w in 0..graph.n_workers() {
                    let d = rng.exponential(1.0 / mean_s);
                    graph.delay_worker(w, d);
                }
            }
            ScenarioModel::SpotRevocation { prob } => {
                // per-worker hit draw in worker-id order; a revoked
                // worker pays a seeded restart penalty before its ops
                // run (both uniforms drawn unconditionally so the
                // stream per worker is fixed, like `straggler`)
                let mut rng = Rng::new(seed ^ SPOT_REVOCATION_TAG);
                for w in 0..graph.n_workers() {
                    let hit = rng.chance(prob);
                    let penalty = rng.uniform(1.0, 3.0);
                    if hit {
                        graph.delay_worker(w, penalty);
                    }
                }
            }
        }
    }
}

/// A possibly-composite scenario: zero or more [`ScenarioModel`]
/// components applied in canonical order (cold-start, then straggler,
/// then bandwidth-jitter). The wire name joins component names with
/// `+` — `"cold-start+bandwidth-jitter"` — and `"deterministic"` is
/// the empty composite. `"jitter"` is accepted as shorthand for
/// `"bandwidth-jitter"` on input; [`ScenarioSpec::name`] always emits
/// canonical component names in canonical order, so
/// `parse(spec.name()) == Some(spec)` for every spec `parse` accepts.
///
/// Each component draws from its own xor-tagged RNG stream (see
/// [`ScenarioModel::apply`]), so composing scenarios never perturbs the
/// draws a component would make alone: `cold-start+straggler` at seed 7
/// uses exactly the cold-start draws of `cold-start` at seed 7 plus
/// exactly the straggler draws of `straggler` at seed 7.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScenarioSpec {
    components: Vec<ScenarioModel>,
}

impl ScenarioSpec {
    /// The empty composite: no perturbation.
    pub fn deterministic() -> Self {
        Self { components: Vec::new() }
    }

    /// Canonical ordering rank of a component (draw/application order).
    fn rank(m: &ScenarioModel) -> usize {
        match m {
            ScenarioModel::Deterministic => 0,
            ScenarioModel::ColdStart { .. } => 1,
            ScenarioModel::Straggler { .. } => 2,
            ScenarioModel::BandwidthJitter { .. } => 3,
            ScenarioModel::FlakyNetwork { .. } => 4,
            ScenarioModel::BandwidthDecay { .. } => 5,
            ScenarioModel::ColdStartStorm { .. } => 6,
            ScenarioModel::SpotRevocation { .. } => 7,
        }
    }

    /// Wrap a single model (`Deterministic` becomes the empty spec).
    pub fn from_model(m: ScenarioModel) -> Self {
        match m {
            ScenarioModel::Deterministic => Self::deterministic(),
            other => Self { components: vec![other] },
        }
    }

    /// Parse a wire name: component names (canonical, or the `jitter`
    /// shorthand) joined by `+`. Components may appear in any order and
    /// are normalized to canonical order; duplicates and mixing
    /// `deterministic` with anything else are rejected.
    pub fn parse(s: &str) -> Option<ScenarioSpec> {
        let parts: Vec<&str> = s.split('+').collect();
        if parts.len() == 1 && parts[0] == "deterministic" {
            return Some(Self::deterministic());
        }
        let mut components = Vec::new();
        for part in parts {
            let canonical = if part == "jitter" { "bandwidth-jitter" } else { part };
            let m = ScenarioModel::parse(canonical)?;
            if m.is_deterministic() {
                // "deterministic+X" is a contradiction, not a composite
                return None;
            }
            if components.iter().any(|c: &ScenarioModel| c.as_str() == m.as_str()) {
                return None;
            }
            components.push(m);
        }
        components.sort_by_key(Self::rank);
        Some(Self { components })
    }

    /// Stable wire name; inverse of [`ScenarioSpec::parse`] up to
    /// normalization (canonical component order, canonical names).
    pub fn name(&self) -> String {
        if self.components.is_empty() {
            "deterministic".to_string()
        } else {
            self.components
                .iter()
                .map(|c| c.as_str())
                .collect::<Vec<_>>()
                .join("+")
        }
    }

    pub fn is_deterministic(&self) -> bool {
        self.components.is_empty()
    }

    /// The components in canonical (application) order.
    pub fn components(&self) -> &[ScenarioModel] {
        &self.components
    }

    /// The component of the same kind as `probe`, if present.
    pub fn component(&self, probe: &str) -> Option<&ScenarioModel> {
        self.components.iter().find(|c| c.as_str() == probe)
    }

    /// Perturb `graph` in place: each component applies in canonical
    /// order, each drawing from its own tagged stream of `seed`.
    pub fn apply(&self, graph: &mut FlowGraph, seed: u64) {
        for c in &self.components {
            c.apply(graph, seed);
        }
    }

    /// Human-readable list of accepted forms (error messages, help).
    pub const SYNTAX: &'static str =
        "deterministic|cold-start|straggler|bandwidth-jitter|flaky-network|\
         bandwidth-decay|cold-start-storm|spot-revocation, \
         or a `+`-joined composite like cold-start+jitter";
}

#[cfg(test)]
mod tests {
    use super::super::{execute, Node};
    use super::*;

    fn demo_graph() -> FlowGraph {
        let mut g = FlowGraph::new();
        for w in 0..4 {
            let c = g.add(Node::compute(w, 1.0));
            let u = g.add(Node::transfer(w, true, 0.5).after(vec![c]));
            g.add(Node::compute(w, 1.0).after(vec![u]));
        }
        g
    }

    #[test]
    fn names_round_trip() {
        for name in ScenarioModel::NAMES {
            let s = ScenarioModel::parse(name).unwrap();
            assert_eq!(s.as_str(), name);
        }
        assert!(ScenarioModel::parse("chaos-monkey").is_none());
    }

    #[test]
    fn deterministic_is_identity() {
        let mut a = demo_graph();
        let b = demo_graph();
        ScenarioModel::Deterministic.apply(&mut a, 7);
        assert_eq!(execute(&a).makespan, execute(&b).makespan);
    }

    #[test]
    fn same_seed_replays_bit_identically() {
        for name in [
            "cold-start",
            "straggler",
            "bandwidth-jitter",
            "flaky-network",
            "bandwidth-decay",
            "cold-start-storm",
            "spot-revocation",
        ] {
            let s = ScenarioModel::parse(name).unwrap();
            let mut a = demo_graph();
            let mut b = demo_graph();
            s.apply(&mut a, 42);
            s.apply(&mut b, 42);
            let (ra, rb) = (execute(&a), execute(&b));
            assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits(), "{name}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        for name in [
            "cold-start",
            "straggler",
            "bandwidth-jitter",
            "bandwidth-decay",
            "cold-start-storm",
        ] {
            // flaky-network and spot-revocation are excluded here: their
            // draws are discrete, so two seeds CAN coincide on a small
            // demo graph (the larger replay tests cover seed
            // sensitivity)
            let s = ScenarioModel::parse(name).unwrap();
            let mut a = demo_graph();
            let mut b = demo_graph();
            s.apply(&mut a, 1);
            s.apply(&mut b, 2);
            assert_ne!(
                execute(&a).makespan.to_bits(),
                execute(&b).makespan.to_bits(),
                "{name}: seeds 1 and 2 gave identical timelines"
            );
        }
    }

    #[test]
    fn cold_start_only_delays() {
        let base = execute(&demo_graph()).makespan;
        let mut g = demo_graph();
        ScenarioModel::parse("cold-start").unwrap().apply(&mut g, 3);
        assert!(execute(&g).makespan >= base);
    }

    #[test]
    fn straggler_stretches_compute_only() {
        let mut g = demo_graph();
        let before: f64 = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Transfer)
            .map(|n| n.work)
            .sum();
        ScenarioModel::parse("straggler").unwrap().apply(&mut g, 5);
        let after: f64 = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Transfer)
            .map(|n| n.work)
            .sum();
        assert_eq!(before.to_bits(), after.to_bits());
        assert!(g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Compute)
            .all(|n| n.work >= 1.0));
    }

    #[test]
    fn flaky_network_delays_transfers_only() {
        // scan seeds for one where at least one transfer is hit (the
        // draw is deterministic per seed, so this terminates instantly)
        let m = ScenarioModel::parse("flaky-network").unwrap();
        let ScenarioModel::FlakyNetwork { timeout_s, .. } = m else {
            panic!("wrong variant")
        };
        let mut hit_seed = None;
        for seed in 0..64u64 {
            let mut g = demo_graph();
            m.apply(&mut g, seed);
            if g.nodes
                .iter()
                .any(|n| n.kind == OpKind::Transfer && n.delay >= timeout_s)
            {
                hit_seed = Some(seed);
                break;
            }
        }
        let seed = hit_seed.expect("no seed in 0..64 dropped a transfer");
        let base = execute(&demo_graph()).makespan;
        let mut g = demo_graph();
        m.apply(&mut g, seed);
        // compute/fixed nodes untouched; work amounts untouched
        for (a, b) in g.nodes.iter().zip(&demo_graph().nodes) {
            assert_eq!(a.work.to_bits(), b.work.to_bits());
            if a.kind != OpKind::Transfer {
                assert_eq!(a.delay.to_bits(), b.delay.to_bits());
            }
        }
        // a dead attempt only ever adds waiting
        assert!(execute(&g).makespan >= base);
    }

    #[test]
    fn spec_parses_singles_like_model() {
        for name in ScenarioModel::NAMES {
            let spec = ScenarioSpec::parse(name).unwrap();
            assert_eq!(spec.name(), name);
            if name == "deterministic" {
                assert!(spec.is_deterministic());
                assert!(spec.components().is_empty());
            } else {
                assert_eq!(spec.components().len(), 1);
                assert_eq!(
                    spec.components()[0],
                    ScenarioModel::parse(name).unwrap()
                );
            }
        }
        assert!(ScenarioSpec::parse("chaos-monkey").is_none());
    }

    #[test]
    fn spec_composites_normalize_and_round_trip() {
        // the ISSUE's ergonomic shorthand
        let spec = ScenarioSpec::parse("cold-start+jitter").unwrap();
        assert_eq!(spec.name(), "cold-start+bandwidth-jitter");
        assert_eq!(spec.components().len(), 2);
        // any input order normalizes to canonical order
        let swapped = ScenarioSpec::parse("bandwidth-jitter+cold-start").unwrap();
        assert_eq!(swapped, spec);
        // name() round-trips through parse for every accepted spec
        assert_eq!(ScenarioSpec::parse(&spec.name()).unwrap(), spec);
        let triple =
            ScenarioSpec::parse("straggler+cold-start+jitter").unwrap();
        assert_eq!(triple.name(), "cold-start+straggler+bandwidth-jitter");
        assert_eq!(ScenarioSpec::parse(&triple.name()).unwrap(), triple);
        // flaky-network composes and canonicalizes last
        let flaky =
            ScenarioSpec::parse("flaky-network+cold-start").unwrap();
        assert_eq!(flaky.name(), "cold-start+flaky-network");
        assert_eq!(ScenarioSpec::parse(&flaky.name()).unwrap(), flaky);
        assert!(ScenarioSpec::parse("flaky-network+flaky-network").is_none());
    }

    #[test]
    fn spec_rejects_duplicates_and_deterministic_mixes() {
        assert!(ScenarioSpec::parse("cold-start+cold-start").is_none());
        assert!(ScenarioSpec::parse("jitter+bandwidth-jitter").is_none());
        assert!(ScenarioSpec::parse("deterministic+cold-start").is_none());
        assert!(ScenarioSpec::parse("cold-start+deterministic").is_none());
        assert!(ScenarioSpec::parse("").is_none());
        assert!(ScenarioSpec::parse("cold-start+").is_none());
    }

    #[test]
    fn composite_apply_equals_sequential_components() {
        let mut composite = demo_graph();
        ScenarioSpec::parse("cold-start+straggler")
            .unwrap()
            .apply(&mut composite, 9);
        let mut sequential = demo_graph();
        ScenarioModel::parse("cold-start").unwrap().apply(&mut sequential, 9);
        ScenarioModel::parse("straggler").unwrap().apply(&mut sequential, 9);
        assert_eq!(
            execute(&composite).makespan.to_bits(),
            execute(&sequential).makespan.to_bits()
        );
        // and a composite replays bit-identically like every scenario
        let mut again = demo_graph();
        ScenarioSpec::parse("cold-start+straggler")
            .unwrap()
            .apply(&mut again, 9);
        assert_eq!(
            execute(&composite).makespan.to_bits(),
            execute(&again).makespan.to_bits()
        );
    }

    #[test]
    fn decay_curve_is_monotone_and_floored() {
        let mut prev = 1.0;
        for step in 0..400 {
            let m = decay_curve(0.02, 0.3, step);
            assert!(m <= prev + 1e-12, "step {step}: {m} > {prev}");
            assert!(m >= 0.3, "step {step}: {m} fell through the floor");
            prev = m;
        }
        assert!((decay_curve(0.02, 0.3, 0) - 1.0).abs() < 1e-12);
        // far past the knee the floor holds exactly
        assert_eq!(decay_curve(0.02, 0.3, 399), 0.3);
    }

    #[test]
    fn time_varying_lenses_compose_with_static_ones() {
        let spec =
            ScenarioSpec::parse("cold-start+bandwidth-decay+spot-revocation")
                .unwrap();
        assert_eq!(
            spec.name(),
            "cold-start+bandwidth-decay+spot-revocation"
        );
        assert_eq!(ScenarioSpec::parse(&spec.name()).unwrap(), spec);
        // storm is a distinct lens from plain cold-start, and they mix
        let storm = ScenarioSpec::parse("cold-start-storm+cold-start").unwrap();
        assert_eq!(storm.name(), "cold-start+cold-start-storm");
        assert!(
            ScenarioSpec::parse("cold-start-storm+cold-start-storm").is_none()
        );
    }

    #[test]
    fn component_lookup_finds_kinds() {
        let spec = ScenarioSpec::parse("cold-start+jitter").unwrap();
        assert!(spec.component("cold-start").is_some());
        assert!(spec.component("bandwidth-jitter").is_some());
        assert!(spec.component("straggler").is_none());
    }
}
