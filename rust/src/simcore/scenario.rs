//! Pluggable serverless scenarios: seeded perturbations applied to a
//! [`FlowGraph`] before execution, turning the simulator from a pure
//! validation tool into a scenario lab.
//!
//! Related serverless-training studies show the real environment is
//! dominated by effects a deterministic model cannot express — container
//! cold starts, stragglers and bandwidth jitter ("Towards Demystifying
//! Serverless Machine Learning Training"; SMLT's adaptive scaling is
//! motivated by exactly this variance). Each scenario perturbs one of
//! those axes, deterministically from a `u64` seed (xoshiro256** via
//! [`util::rng`](crate::util::rng)): same seed + scenario ⇒ bit-identical
//! simulation, different seeds ⇒ different draws. Every draw happens in
//! worker- or node-id order, never from iteration over unordered
//! containers, which is what makes replay exact.

use crate::util::rng::Rng;

use super::graph::{FlowGraph, OpKind};

/// A named, seeded perturbation model.
///
/// The wire names (config `"scenario"` key, `--scenario` flag) are
/// `deterministic`, `cold-start`, `straggler` and `bandwidth-jitter`;
/// [`ScenarioModel::parse`] is the inverse of [`ScenarioModel::as_str`].
/// Parameters are fixed per name so a name round-trips losslessly
/// through configs and plan artifacts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioModel {
    /// No perturbation; the seed is ignored (and no RNG is consumed).
    Deterministic,
    /// Each worker's function instance boots `Exp(1/mean_s)` seconds
    /// late — every node of that worker starts no earlier.
    ColdStart { mean_s: f64 },
    /// Per-worker compute slowdown: with probability `prob` a worker is
    /// a straggler (compute stretched by up to `slowdown`×); every
    /// worker also gets a small continuous background factor so that
    /// different seeds always produce different timelines.
    Straggler { prob: f64, slowdown: f64 },
    /// Lognormal bandwidth variation: every transfer (and closed-form
    /// sync occupancy) is stretched by `exp(σ·N(0,1))`, compute by the
    /// paper-calibrated σ/3 — the Table 3 "measured" noise.
    BandwidthJitter { sigma: f64 },
}

impl ScenarioModel {
    /// Stable wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ScenarioModel::Deterministic => "deterministic",
            ScenarioModel::ColdStart { .. } => "cold-start",
            ScenarioModel::Straggler { .. } => "straggler",
            ScenarioModel::BandwidthJitter { .. } => "bandwidth-jitter",
        }
    }

    /// Parse a wire name into the scenario with its canonical
    /// parameters. Inverse of [`ScenarioModel::as_str`].
    pub fn parse(s: &str) -> Option<ScenarioModel> {
        match s {
            "deterministic" => Some(ScenarioModel::Deterministic),
            "cold-start" => Some(ScenarioModel::ColdStart { mean_s: 2.0 }),
            "straggler" => {
                Some(ScenarioModel::Straggler { prob: 0.2, slowdown: 2.5 })
            }
            "bandwidth-jitter" => {
                Some(ScenarioModel::BandwidthJitter { sigma: 0.15 })
            }
            _ => None,
        }
    }

    /// Every accepted wire name (error messages, CLI help).
    pub const NAMES: [&'static str; 4] =
        ["deterministic", "cold-start", "straggler", "bandwidth-jitter"];

    pub fn is_deterministic(&self) -> bool {
        matches!(self, ScenarioModel::Deterministic)
    }

    /// Perturb `graph` in place, deterministically from `seed`.
    pub fn apply(&self, graph: &mut FlowGraph, seed: u64) {
        match *self {
            ScenarioModel::Deterministic => {}
            ScenarioModel::ColdStart { mean_s } => {
                let mut rng = Rng::new(seed ^ 0xC01D_57A7);
                for w in 0..graph.n_workers() {
                    graph.delay_worker(w, rng.exponential(1.0 / mean_s));
                }
            }
            ScenarioModel::Straggler { prob, slowdown } => {
                let mut rng = Rng::new(seed ^ 0x57A6_61E6);
                let factors: Vec<f64> = (0..graph.n_workers())
                    .map(|_| {
                        // draw both branches' uniforms unconditionally so
                        // the stream consumed per worker is fixed
                        let hit = rng.chance(prob);
                        let heavy = rng.uniform(1.5, slowdown.max(1.5));
                        let background = rng.uniform(1.0, 1.05);
                        if hit {
                            heavy
                        } else {
                            background
                        }
                    })
                    .collect();
                for node in &mut graph.nodes {
                    if node.kind == OpKind::Compute {
                        node.work *= factors[node.worker];
                    }
                }
            }
            ScenarioModel::BandwidthJitter { sigma } => {
                let mut rng = Rng::new(seed ^ 0xBA2D_317E);
                for node in &mut graph.nodes {
                    let sg = match node.kind {
                        OpKind::Compute => sigma / 3.0,
                        OpKind::Transfer | OpKind::Fixed => sigma,
                    };
                    // lognormal factor around 1 (a bandwidth dip makes
                    // the transfer longer)
                    node.work *= (sg * rng.normal()).exp();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{execute, Node};
    use super::*;

    fn demo_graph() -> FlowGraph {
        let mut g = FlowGraph::new();
        for w in 0..4 {
            let c = g.add(Node::compute(w, 1.0));
            let u = g.add(Node::transfer(w, true, 0.5).after(vec![c]));
            g.add(Node::compute(w, 1.0).after(vec![u]));
        }
        g
    }

    #[test]
    fn names_round_trip() {
        for name in ScenarioModel::NAMES {
            let s = ScenarioModel::parse(name).unwrap();
            assert_eq!(s.as_str(), name);
        }
        assert!(ScenarioModel::parse("chaos-monkey").is_none());
    }

    #[test]
    fn deterministic_is_identity() {
        let mut a = demo_graph();
        let b = demo_graph();
        ScenarioModel::Deterministic.apply(&mut a, 7);
        assert_eq!(execute(&a).makespan, execute(&b).makespan);
    }

    #[test]
    fn same_seed_replays_bit_identically() {
        for name in ["cold-start", "straggler", "bandwidth-jitter"] {
            let s = ScenarioModel::parse(name).unwrap();
            let mut a = demo_graph();
            let mut b = demo_graph();
            s.apply(&mut a, 42);
            s.apply(&mut b, 42);
            let (ra, rb) = (execute(&a), execute(&b));
            assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits(), "{name}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        for name in ["cold-start", "straggler", "bandwidth-jitter"] {
            let s = ScenarioModel::parse(name).unwrap();
            let mut a = demo_graph();
            let mut b = demo_graph();
            s.apply(&mut a, 1);
            s.apply(&mut b, 2);
            assert_ne!(
                execute(&a).makespan.to_bits(),
                execute(&b).makespan.to_bits(),
                "{name}: seeds 1 and 2 gave identical timelines"
            );
        }
    }

    #[test]
    fn cold_start_only_delays() {
        let base = execute(&demo_graph()).makespan;
        let mut g = demo_graph();
        ScenarioModel::parse("cold-start").unwrap().apply(&mut g, 3);
        assert!(execute(&g).makespan >= base);
    }

    #[test]
    fn straggler_stretches_compute_only() {
        let mut g = demo_graph();
        let before: f64 = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Transfer)
            .map(|n| n.work)
            .sum();
        ScenarioModel::parse("straggler").unwrap().apply(&mut g, 5);
        let after: f64 = g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Transfer)
            .map(|n| n.work)
            .sum();
        assert_eq!(before.to_bits(), after.to_bits());
        assert!(g
            .nodes
            .iter()
            .filter(|n| n.kind == OpKind::Compute)
            .all(|n| n.work >= 1.0));
    }
}
