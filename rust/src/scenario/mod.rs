//! The scenario **Injector**: the runtime half of the scenario lab.
//!
//! PR 3's [`ScenarioModel`]s perturb the *simulator* (a seeded pass over
//! the [`FlowGraph`](crate::simcore::FlowGraph) before execution). This
//! module threads the same seeded draws into the **real** execution
//! path, so `train --scenario straggler --seed 7` replays the lifecycle
//! the planner evaluated: [`ThrottledStore`](crate::platform::ThrottledStore)
//! handles are scaled by per-worker bandwidth/latency multipliers, the
//! Function Manager's checkpoint/restart path (§3.1 step 8) charges a
//! scenario-scaled cold start per generation, and — because a scenario
//! run's whole point is replayable comparison — the function lifecycle
//! and the report's timeline run on a deterministic virtual clock
//! instead of the wall clock (see `coordinator::worker`).
//!
//! Determinism contract (mirrors `simcore::scenario`):
//! * every per-worker draw happens **strictly in worker-id order** at
//!   construction, from `util::rng` streams tagged with the same xor
//!   constants as the simulator — `cold-start` at seed 7 draws the
//!   *identical* generation-0 delays the simulator applies to its
//!   workers;
//! * per-*generation* cold-start draws (the simulator only ever sees
//!   generation 0) come from a stream keyed on `(worker, generation)`,
//!   so they are independent of thread interleaving;
//! * composite [`ScenarioSpec`]s apply components in canonical order,
//!   each from its own tagged stream, so composing never changes the
//!   draws a component would make alone.
//!
//! Real-path mapping of each lens (DESIGN.md §10): the simulator can
//! stretch a worker's compute, but the real path executes real
//! kernels, so a `straggler`'s compute factor maps onto its *storage*
//! path (bandwidth divided by, latency multiplied by the factor) and
//! onto the virtual clock; `bandwidth-jitter` draws one per-worker
//! lognormal transfer factor (the simulator draws per node — the
//! static per-worker form is the runtime analogue) plus the σ/3
//! compute factor; `cold-start` adds exponential delays to every
//! generation's cold start; `flaky-network` drops `get_blocking`
//! attempts through the worker's [`FlakyStore`] handle (per-(worker,
//! key) seeded decisions, at most one drop per key — the simulator
//! charges the dead attempt's timeout per transfer node) and the
//! trainer's [`RetryStore`](crate::platform::RetryStore) middleware
//! absorbs them, exercising the retry path for real. Bandwidth
//! multipliers only bite when the run has a finite `throttle`; the
//! lens never touches correctness, only timing (flaky drops surface as
//! retry counts in the report, never as wrong data).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::platform::{ObjectStore, StoreFuture};
use crate::simcore::{
    cold_start_delays, decay_curve, straggler_factors, ScenarioModel,
    ScenarioSpec, BANDWIDTH_DECAY_TAG, BANDWIDTH_JITTER_TAG, COLD_START_TAG,
    COLD_START_STORM_TAG, FLAKY_NETWORK_TAG, SPOT_REVOCATION_TAG,
};
use crate::util::rng::Rng;

/// One worker's multiplicative lens on the real execution path.
/// Identity (`1.0` everywhere) under the deterministic scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerLens {
    /// Compute slowdown factor (≥ 1 under `straggler`): scales the
    /// virtual per-iteration time and, through `bandwidth_mult` /
    /// `latency_mult`, the worker's storage path.
    pub compute_mult: f64,
    /// Multiplies the worker's throttled uplink/downlink bandwidth
    /// (< 1 slows the worker).
    pub bandwidth_mult: f64,
    /// Multiplies the worker's per-access storage latency.
    pub latency_mult: f64,
}

impl WorkerLens {
    pub const IDENTITY: WorkerLens =
        WorkerLens { compute_mult: 1.0, bandwidth_mult: 1.0, latency_mult: 1.0 };
}

/// Seeded, deterministic perturbation provider for the real trainer.
#[derive(Debug, Clone)]
pub struct Injector {
    spec: ScenarioSpec,
    seed: u64,
    lenses: Vec<WorkerLens>,
    /// Generation-0 cold-start delays, drawn at construction from the
    /// simulator's exact stream (empty unless `cold-start` is active).
    cold_gen0: Vec<f64>,
    cold_mean_s: Option<f64>,
    /// `(prob, timeout_s)` when the `flaky-network` component is
    /// active: each worker's store handle drops `get_blocking` attempts
    /// with per-(worker, key) seeded decisions (see [`FlakyStore`]).
    flaky: Option<(f64, f64)>,
    /// `(rate, floor)` when the `bandwidth-decay` component is active:
    /// step `t`'s store bandwidth multiplier is `decay_curve(rate,
    /// floor, t)` plus a seeded per-(tenant, worker, step) wobble.
    decay: Option<(f64, f64)>,
    /// `(start_step, end_step, mean_s)` when the `cold-start-storm`
    /// component is active. The half-open step window `[start, end)` is
    /// drawn at construction from the seed *alone*, so every tenant of
    /// a fleet sees the identical storm window (that is the
    /// correlation).
    storm: Option<(usize, usize, f64)>,
    /// Revocation probability when the `spot-revocation` component is
    /// active: each (tenant, worker, step) is revoked independently.
    revoke: Option<f64>,
}

/// Mix a `(tenant, worker, step)` coordinate into one stream key. The
/// draws keyed off this are pure functions of the coordinate (plus the
/// seed and the lens tag), so they are order-independent: any
/// scheduler interleaving replays byte-identically, and the strict
/// (tenant, worker, step) draw order of the fleet contract is
/// trivially satisfied.
fn step_key(tenant: usize, worker: usize, step: usize) -> u64 {
    (tenant as u64)
        .wrapping_mul(0xA24B_AED4_963E_E407)
        ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((step as u64) << 21)
}

impl Injector {
    /// Draw every per-worker lens for `n_workers` workers (worker id =
    /// `stage * dp + replica`, the `FunctionInstance::launch` id), in
    /// worker-id order, component by component in canonical order.
    pub fn new(spec: &ScenarioSpec, seed: u64, n_workers: usize) -> Self {
        let mut lenses = vec![WorkerLens::IDENTITY; n_workers];
        let mut cold_gen0 = Vec::new();
        let mut cold_mean_s = None;
        let mut flaky = None;
        let mut decay = None;
        let mut storm = None;
        let mut revoke = None;
        for component in spec.components() {
            match *component {
                ScenarioModel::Deterministic => {}
                ScenarioModel::ColdStart { mean_s } => {
                    // the simulator's exact per-worker delay stream
                    cold_gen0 = cold_start_delays(seed, mean_s, n_workers);
                    cold_mean_s = Some(mean_s);
                }
                ScenarioModel::Straggler { prob, slowdown } => {
                    // the simulator's exact per-worker factor stream
                    let factors =
                        straggler_factors(seed, prob, slowdown, n_workers);
                    for (lens, factor) in lenses.iter_mut().zip(factors) {
                        lens.compute_mult *= factor;
                        lens.bandwidth_mult /= factor;
                        lens.latency_mult *= factor;
                    }
                }
                ScenarioModel::FlakyNetwork { prob, timeout_s } => {
                    // no per-worker lens: the drop decisions are pure
                    // functions of (seed, worker, key), drawn lazily by
                    // the worker's FlakyStore handle
                    flaky = Some((prob, timeout_s));
                }
                ScenarioModel::BandwidthJitter { sigma } => {
                    let mut rng = Rng::new(seed ^ BANDWIDTH_JITTER_TAG);
                    for lens in &mut lenses {
                        // lognormal around 1: a bandwidth dip stretches
                        // transfers by `t`, compute by the σ/3 factor
                        // (per worker — the runtime analogue of the
                        // simulator's per-node draws, same tagged
                        // stream)
                        let t = (sigma * rng.normal()).exp();
                        let c = (sigma / 3.0 * rng.normal()).exp();
                        lens.bandwidth_mult /= t;
                        lens.latency_mult *= t;
                        lens.compute_mult *= c;
                    }
                }
                ScenarioModel::BandwidthDecay { rate, floor } => {
                    // no static per-worker lens: the multiplier is a
                    // pure function of (seed, tenant, worker, step),
                    // drawn lazily by step_bandwidth_mult
                    decay = Some((rate, floor));
                }
                ScenarioModel::ColdStartStorm { mean_s } => {
                    // the storm window depends on the seed alone —
                    // NOT on n_workers or the tenant — so concurrent
                    // tenants are hit by the same burst
                    let mut rng = Rng::new(seed ^ COLD_START_STORM_TAG);
                    let start = rng.index(32);
                    let len = 4 + rng.index(8);
                    storm = Some((start, start + len, mean_s));
                }
                ScenarioModel::SpotRevocation { prob } => {
                    // per-(tenant, worker, step) decisions drawn lazily
                    // by step_revoked
                    revoke = Some(prob);
                }
            }
        }
        Self {
            spec: spec.clone(),
            seed,
            lenses,
            cold_gen0,
            cold_mean_s,
            flaky,
            decay,
            storm,
            revoke,
        }
    }

    /// An inactive injector (identity lenses, base cold starts only).
    pub fn inactive(n_workers: usize) -> Self {
        Self::new(&ScenarioSpec::deterministic(), 0, n_workers)
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn n_workers(&self) -> usize {
        self.lenses.len()
    }

    /// Whether any perturbation is active.
    pub fn is_active(&self) -> bool {
        !self.spec.is_deterministic()
    }

    pub fn worker(&self, worker: usize) -> WorkerLens {
        self.lenses.get(worker).copied().unwrap_or(WorkerLens::IDENTITY)
    }

    /// Seconds a cold start charges `worker` at `generation`: the
    /// platform/tier base plus, under `cold-start`, the exponential
    /// draw. Generation 0 uses the simulator's exact per-worker stream;
    /// later generations (which only the real path reaches) draw from a
    /// `(worker, generation)`-keyed stream so the value is independent
    /// of when other workers restart.
    pub fn cold_start_s(&self, worker: usize, generation: u32, base_s: f64) -> f64 {
        let extra = match self.cold_mean_s {
            None => 0.0,
            Some(mean_s) => {
                if generation == 0 {
                    self.cold_gen0.get(worker).copied().unwrap_or(0.0)
                } else {
                    let key = (worker as u64)
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ ((generation as u64) << 17)
                        ^ COLD_START_TAG;
                    Rng::new(self.seed ^ key).exponential(1.0 / mean_s)
                }
            }
        };
        base_s + extra
    }

    /// The worker's deterministic virtual per-iteration time given the
    /// scenario-free base (the plan's predicted `t_iter`, or a unit
    /// tick): the straggler/jitter compute factor stretches it.
    pub fn iter_virtual_s(&self, worker: usize, base_s: f64) -> f64 {
        base_s * self.worker(worker).compute_mult
    }

    /// The slowest worker's virtual per-iteration time — what gates a
    /// pipelined iteration end-to-end.
    pub fn max_iter_virtual_s(&self, base_s: f64) -> f64 {
        (0..self.lenses.len().max(1))
            .map(|w| self.iter_virtual_s(w, base_s))
            .fold(0.0, f64::max)
    }

    /// `(prob, timeout_s)` of the `flaky-network` component, when
    /// active.
    pub fn flaky(&self) -> Option<(f64, f64)> {
        self.flaky
    }

    /// Whether any per-*step* time-varying component is active
    /// (`bandwidth-decay`, `cold-start-storm` or `spot-revocation`).
    pub fn is_time_varying(&self) -> bool {
        self.decay.is_some() || self.storm.is_some() || self.revoke.is_some()
    }

    /// The `cold-start-storm` step window `[start, end)`, when active.
    /// A pure function of the seed — identical for every tenant.
    pub fn storm_window(&self) -> Option<(usize, usize)> {
        self.storm.map(|(lo, hi, _)| (lo, hi))
    }

    /// Store-bandwidth multiplier of virtual step `step` for `tenant`'s
    /// `worker` under `bandwidth-decay`: the deterministic decay curve
    /// times a small seeded wobble keyed on the full (tenant, worker,
    /// step) coordinate. `1.0` when the component is inactive.
    pub fn step_bandwidth_mult(
        &self,
        tenant: usize,
        worker: usize,
        step: usize,
    ) -> f64 {
        match self.decay {
            None => 1.0,
            Some((rate, floor)) => {
                let key = step_key(tenant, worker, step) ^ BANDWIDTH_DECAY_TAG;
                decay_curve(rate, floor, step)
                    * Rng::new(self.seed ^ key).uniform(0.97, 1.0)
            }
        }
    }

    /// Extra start latency (seconds) `cold-start-storm` charges
    /// `tenant`'s `worker` at virtual step `step`: an exponential draw
    /// keyed on the full coordinate when the step falls inside the
    /// seeded storm window, else `0.0`.
    pub fn storm_extra_s(&self, tenant: usize, worker: usize, step: usize) -> f64 {
        match self.storm {
            None => 0.0,
            Some((lo, hi, mean_s)) => {
                if step < lo || step >= hi {
                    return 0.0;
                }
                let key = step_key(tenant, worker, step) ^ COLD_START_STORM_TAG;
                Rng::new(self.seed ^ key).exponential(1.0 / mean_s)
            }
        }
    }

    /// Whether `spot-revocation` revokes `tenant`'s `worker` at virtual
    /// step `step` (a pure function of the coordinate). A revoked
    /// tenant releases its workers and re-queues for admission.
    pub fn step_revoked(&self, tenant: usize, worker: usize, step: usize) -> bool {
        match self.revoke {
            None => false,
            Some(prob) => {
                let key = step_key(tenant, worker, step) ^ SPOT_REVOCATION_TAG;
                Rng::new(self.seed ^ key).chance(prob)
            }
        }
    }

    /// The slowest worker's time-varying iteration stretch at `step`:
    /// the reciprocal of the worst per-step bandwidth multiplier across
    /// `tenant`'s workers (a decayed store stretches the communication
    /// the tick gates on), plus the worst storm delay as an additive
    /// term. Returns `(mult, extra_s)` — `(1.0, 0.0)` when no
    /// time-varying component is active.
    pub fn step_stretch(
        &self,
        tenant: usize,
        n_workers: usize,
        step: usize,
    ) -> (f64, f64) {
        if !self.is_time_varying() {
            return (1.0, 0.0);
        }
        let mut mult = 1.0f64;
        let mut extra = 0.0f64;
        for w in 0..n_workers {
            let bw = self.step_bandwidth_mult(tenant, w, step);
            if bw.is_finite() && bw > 0.0 {
                mult = mult.max(1.0 / bw);
            }
            extra = extra.max(self.storm_extra_s(tenant, w, step));
        }
        (mult, extra)
    }
}

/// FNV-1a over a key string — the stable hash [`FlakyStore`] mixes into
/// its per-key drop stream (std's `DefaultHasher` is explicitly not
/// stable across releases, and replay must be).
fn fnv1a(key: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The `flaky-network` lens on a worker's store handle: `get_blocking`
/// attempts are dropped with probability `prob`, at most once per key,
/// by decisions that are pure functions of `(seed, worker, key)` — so
/// the drop pattern is independent of thread interleaving and replays
/// byte-identically, and a single retry always clears a drop (which is
/// why it composes with [`RetryStore`](crate::platform::RetryStore)).
/// An injected drop fails *instantly* with the transient error class
/// ([`TRANSIENT_ERROR_MARKER`](crate::platform::TRANSIENT_ERROR_MARKER))
/// and never touches the inner store, so storage op counts stay
/// deterministic too.
pub struct FlakyStore {
    inner: Arc<dyn ObjectStore>,
    seed: u64,
    worker: u64,
    prob: f64,
    dropped: Mutex<std::collections::HashSet<String>>,
    timeouts: Arc<AtomicU64>,
}

impl FlakyStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        seed: u64,
        worker: usize,
        prob: f64,
    ) -> Self {
        Self {
            inner,
            seed,
            worker: worker as u64,
            prob,
            dropped: Mutex::new(std::collections::HashSet::new()),
            timeouts: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Shared handle on the injected-drop counter (readable after the
    /// store has been type-erased).
    pub fn timeout_counter(&self) -> Arc<AtomicU64> {
        self.timeouts.clone()
    }

    /// Whether THIS attempt on `key` is dropped: the seeded per-key
    /// decision, gated so a key fails at most once (transient by
    /// construction).
    fn should_drop(&self, key: &str) -> bool {
        let mut dropped = self.dropped.lock().unwrap();
        if dropped.contains(key) {
            return false; // already failed once: the retry goes through
        }
        let stream = self.seed
            ^ FLAKY_NETWORK_TAG
            ^ self.worker.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ fnv1a(key);
        if Rng::new(stream).chance(self.prob) {
            dropped.insert(key.to_string());
            true
        } else {
            false
        }
    }
}

impl ObjectStore for FlakyStore {
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.inner.get(key)
    }

    fn get_blocking(&self, key: &str, timeout: Duration) -> Result<Arc<Vec<u8>>> {
        if self.should_drop(key) {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
            // the marker is the retry middleware's classification
            // contract: only errors carrying it are retry-safe
            bail!(
                "{} flaky-network drop: get_blocking gave up on {key:?}",
                crate::platform::TRANSIENT_ERROR_MARKER
            );
        }
        self.inner.get_blocking(key, timeout)
    }

    fn delete(&self, key: &str) {
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }

    fn total_bytes(&self) -> u64 {
        self.inner.total_bytes()
    }

    fn high_water_bytes(&self) -> u64 {
        self.inner.high_water_bytes()
    }

    fn put_async<'a>(&'a self, key: &'a str, data: Vec<u8>) -> StoreFuture<'a, Result<()>> {
        self.inner.put_async(key, data)
    }

    fn get_async<'a>(
        &'a self,
        key: &'a str,
        timeout: Duration,
    ) -> StoreFuture<'a, Result<Arc<Vec<u8>>>> {
        // same seeded per-(worker, key) decision as the blocking path:
        // drops are instant, counted, and never touch the inner store
        if self.should_drop(key) {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
            return Box::pin(async move {
                bail!(
                    "{} flaky-network drop: get_blocking gave up on {key:?}",
                    crate::platform::TRANSIENT_ERROR_MARKER
                )
            });
        }
        self.inner.get_async(key, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{execute, FlowGraph, Node};

    fn spec(name: &str) -> ScenarioSpec {
        ScenarioSpec::parse(name).unwrap()
    }

    #[test]
    fn deterministic_is_identity() {
        let inj = Injector::inactive(4);
        assert!(!inj.is_active());
        for w in 0..4 {
            assert_eq!(inj.worker(w), WorkerLens::IDENTITY);
            assert_eq!(inj.cold_start_s(w, 0, 0.25), 0.25);
            assert_eq!(inj.cold_start_s(w, 3, 0.25), 0.25);
            assert_eq!(inj.iter_virtual_s(w, 2.0), 2.0);
        }
    }

    #[test]
    fn same_seed_replays_identically() {
        for name in
            ["cold-start", "straggler", "bandwidth-jitter", "cold-start+jitter"]
        {
            let a = Injector::new(&spec(name), 7, 6);
            let b = Injector::new(&spec(name), 7, 6);
            for w in 0..6 {
                assert_eq!(a.worker(w), b.worker(w), "{name} worker {w}");
                assert_eq!(
                    a.cold_start_s(w, 2, 0.1).to_bits(),
                    b.cold_start_s(w, 2, 0.1).to_bits()
                );
            }
        }
    }

    #[test]
    fn different_seeds_draw_differently() {
        for name in ["cold-start", "straggler", "bandwidth-jitter"] {
            let a = Injector::new(&spec(name), 1, 6);
            let b = Injector::new(&spec(name), 2, 6);
            let differs = (0..6).any(|w| {
                a.worker(w) != b.worker(w)
                    || a.cold_start_s(w, 0, 0.0) != b.cold_start_s(w, 0, 0.0)
            });
            assert!(differs, "{name}: seeds 1 and 2 drew identical lenses");
        }
    }

    #[test]
    fn cold_start_gen0_matches_the_simulator_stream() {
        // the injector's generation-0 delays must be the exact values
        // ScenarioModel::ColdStart applies to the simulator's workers
        let inj = Injector::new(&spec("cold-start"), 42, 3);
        let mut g = FlowGraph::new();
        for w in 0..3 {
            g.add(Node::compute(w, 1.0));
        }
        let base = execute(&g).makespan;
        ScenarioModel::parse("cold-start").unwrap().apply(&mut g, 42);
        let max_delay = (0..3)
            .map(|w| inj.cold_start_s(w, 0, 0.0))
            .fold(0.0, f64::max);
        assert!(max_delay > 0.0);
        // the delays are continuous draws: a mismatched stream would be
        // off by ~seconds, not float-stepping noise
        let makespan = execute(&g).makespan;
        assert!(
            (makespan - (base + max_delay)).abs() < 1e-9,
            "sim cold-start delays diverge from the injector's: \
             {makespan} vs {}",
            base + max_delay
        );
    }

    #[test]
    fn straggler_lens_matches_sim_parameterization() {
        let inj = Injector::new(&spec("straggler"), 5, 8);
        for w in 0..8 {
            let lens = inj.worker(w);
            // factors live in the sim's [1.0, slowdown] band and the
            // bandwidth/latency mapping is the factor's reciprocal/value
            assert!(lens.compute_mult >= 1.0 && lens.compute_mult <= 2.5);
            assert!((lens.bandwidth_mult - 1.0 / lens.compute_mult).abs() < 1e-12);
            assert!((lens.latency_mult - lens.compute_mult).abs() < 1e-12);
        }
        // background factors make every pair of seeds differ a.s.
        assert!(inj.max_iter_virtual_s(1.0) > 1.0);
    }

    #[test]
    fn straggler_lens_matches_the_simulator_factors() {
        // the lens multipliers must be exactly the factors the simulator
        // multiplies compute work by — shared stream, shared discipline
        let inj = Injector::new(&spec("straggler"), 11, 5);
        let factors = crate::simcore::straggler_factors(11, 0.2, 2.5, 5);
        for (w, f) in factors.iter().enumerate() {
            assert_eq!(inj.worker(w).compute_mult.to_bits(), f.to_bits());
        }
    }

    #[test]
    fn per_generation_cold_draws_are_keyed_and_distinct() {
        let inj = Injector::new(&spec("cold-start"), 9, 2);
        let g1 = inj.cold_start_s(0, 1, 0.0);
        let g2 = inj.cold_start_s(0, 2, 0.0);
        assert!(g1 > 0.0 && g2 > 0.0);
        assert_ne!(g1.to_bits(), g2.to_bits());
        // distinct workers draw independently at the same generation
        assert_ne!(
            inj.cold_start_s(0, 1, 0.0).to_bits(),
            inj.cold_start_s(1, 1, 0.0).to_bits()
        );
        // and the base is always charged on top
        assert_eq!(inj.cold_start_s(0, 1, 1.5), 1.5 + g1);
    }

    #[test]
    fn flaky_component_sets_params_and_keeps_lenses_identity() {
        let inj = Injector::new(&spec("flaky-network"), 7, 4);
        assert!(inj.is_active());
        let (prob, timeout_s) = inj.flaky().unwrap();
        assert!(prob > 0.0 && prob < 1.0);
        assert!(timeout_s > 0.0);
        for w in 0..4 {
            assert_eq!(inj.worker(w), WorkerLens::IDENTITY);
        }
        assert!(Injector::inactive(4).flaky().is_none());
        // composes: the flaky params ride along with other lenses
        let both = Injector::new(&spec("flaky-network+straggler"), 7, 4);
        assert!(both.flaky().is_some());
        assert_ne!(both.worker(0), WorkerLens::IDENTITY);
    }

    #[test]
    fn flaky_store_drops_deterministically_and_at_most_once_per_key() {
        use crate::platform::{MemStore, ObjectStore};
        use std::sync::Arc;
        use std::time::Duration;

        let mem = Arc::new(MemStore::new());
        for i in 0..200 {
            mem.put(&format!("k{i}"), vec![i as u8]).unwrap();
        }
        let store =
            FlakyStore::new(mem.clone(), 7, 3, 0.15);
        let counter = store.timeout_counter();
        let timeout = Duration::from_secs(1);
        let mut first_outcomes = Vec::new();
        for i in 0..200 {
            first_outcomes
                .push(store.get_blocking(&format!("k{i}"), timeout).is_err());
        }
        let drops = first_outcomes.iter().filter(|d| **d).count();
        // prob 0.15 over 200 keys: all-or-nothing would mean a broken
        // stream (P < 1e-13 either way)
        assert!(drops > 0 && drops < 200, "drop count {drops}");
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), drops as u64);
        // second attempt on every key goes through: drops are transient
        for i in 0..200 {
            store.get_blocking(&format!("k{i}"), timeout).unwrap();
        }
        // a fresh handle with the same (seed, worker) replays the exact
        // drop pattern; a different worker or seed draws its own
        let replay = FlakyStore::new(mem.clone(), 7, 3, 0.15);
        let mut same = true;
        let mut other_differs = false;
        let other = FlakyStore::new(mem.clone(), 8, 3, 0.15);
        for (i, was_dropped) in first_outcomes.iter().enumerate() {
            let key = format!("k{i}");
            same &= replay.get_blocking(&key, timeout).is_err() == *was_dropped;
            other_differs |=
                other.get_blocking(&key, timeout).is_err() != *was_dropped;
        }
        assert!(same, "replay diverged from the first run");
        assert!(other_differs, "seed 8 drew the identical 200-key pattern");
    }

    #[test]
    fn flaky_store_composes_with_the_retry_middleware() {
        use crate::platform::{MemStore, ObjectStore, RetryStore};
        use std::sync::Arc;
        use std::time::Duration;

        let mem = Arc::new(MemStore::new());
        for i in 0..100 {
            mem.put(&format!("k{i}"), vec![1]).unwrap();
        }
        let flaky = FlakyStore::new(mem, 7, 0, 0.3);
        let drops = flaky.timeout_counter();
        let store = RetryStore::new(Arc::new(flaky), 1);
        let retries = store.retry_counter();
        // every fetch succeeds despite the injected drops...
        for i in 0..100 {
            store
                .get_blocking(&format!("k{i}"), Duration::from_secs(1))
                .unwrap();
        }
        // ...and each drop cost exactly one retry
        let d = drops.load(std::sync::atomic::Ordering::Relaxed);
        assert!(d > 0, "no drops at prob 0.3 over 100 keys");
        assert_eq!(retries.load(std::sync::atomic::Ordering::Relaxed), d);
    }

    #[test]
    fn time_varying_draws_are_pure_functions_of_the_coordinate() {
        let inj = Injector::new(
            &spec("bandwidth-decay+cold-start-storm+spot-revocation"),
            7,
            4,
        );
        assert!(inj.is_time_varying());
        // static lenses stay identity: time variation is per-step only
        for w in 0..4 {
            assert_eq!(inj.worker(w), WorkerLens::IDENTITY);
        }
        let again = Injector::new(
            &spec("bandwidth-decay+cold-start-storm+spot-revocation"),
            7,
            4,
        );
        let (lo, hi) = inj.storm_window().unwrap();
        assert_eq!(again.storm_window(), Some((lo, hi)));
        assert!(lo < hi && hi <= 32 + 12);
        for tenant in 0..3 {
            for w in 0..4 {
                for step in 0..40 {
                    assert_eq!(
                        inj.step_bandwidth_mult(tenant, w, step).to_bits(),
                        again.step_bandwidth_mult(tenant, w, step).to_bits()
                    );
                    assert_eq!(
                        inj.storm_extra_s(tenant, w, step).to_bits(),
                        again.storm_extra_s(tenant, w, step).to_bits()
                    );
                    assert_eq!(
                        inj.step_revoked(tenant, w, step),
                        again.step_revoked(tenant, w, step)
                    );
                }
            }
        }
    }

    #[test]
    fn bandwidth_decay_follows_the_curve_with_bounded_wobble() {
        let inj = Injector::new(&spec("bandwidth-decay"), 3, 2);
        for step in 0..120 {
            let m = inj.step_bandwidth_mult(0, 0, step);
            let base = crate::simcore::decay_curve(0.02, 0.3, step);
            assert!(m <= base + 1e-12, "step {step}: {m} above the curve");
            assert!(m >= 0.97 * base - 1e-12, "step {step}: wobble too deep");
        }
        // inactive components are exact identity, consuming no draws
        let det = Injector::inactive(2);
        assert_eq!(det.step_bandwidth_mult(0, 0, 5), 1.0);
        assert_eq!(det.storm_extra_s(0, 0, 5), 0.0);
        assert!(!det.step_revoked(0, 0, 5));
        assert_eq!(det.step_stretch(0, 2, 5), (1.0, 0.0));
    }

    #[test]
    fn storm_window_is_shared_but_draws_are_per_coordinate() {
        let inj = Injector::new(&spec("cold-start-storm"), 11, 3);
        let (lo, hi) = inj.storm_window().unwrap();
        // outside the window: no charge, for any tenant
        assert_eq!(inj.storm_extra_s(0, 0, hi), 0.0);
        assert_eq!(inj.storm_extra_s(5, 2, hi + 3), 0.0);
        // inside: every tenant pays, but with its own draw
        let a = inj.storm_extra_s(0, 0, lo);
        let b = inj.storm_extra_s(1, 0, lo);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a.to_bits(), b.to_bits());
        // a different n_workers does not move the window (seed-only)
        let wide = Injector::new(&spec("cold-start-storm"), 11, 64);
        assert_eq!(wide.storm_window(), Some((lo, hi)));
    }

    #[test]
    fn spot_revocation_hits_some_but_not_all_coordinates() {
        let inj = Injector::new(&spec("spot-revocation"), 7, 4);
        let mut hits = 0;
        let mut total = 0;
        for tenant in 0..4 {
            for w in 0..4 {
                for step in 0..40 {
                    total += 1;
                    hits += usize::from(inj.step_revoked(tenant, w, step));
                }
            }
        }
        // prob 0.08 over 640 coordinates: all-or-nothing means a broken
        // stream
        assert!(hits > 0 && hits < total, "revocations {hits}/{total}");
    }

    #[test]
    fn composite_components_draw_their_solo_streams() {
        let solo_cold = Injector::new(&spec("cold-start"), 7, 4);
        let solo_strag = Injector::new(&spec("straggler"), 7, 4);
        let both = Injector::new(&spec("cold-start+straggler"), 7, 4);
        for w in 0..4 {
            assert_eq!(
                both.cold_start_s(w, 0, 0.0).to_bits(),
                solo_cold.cold_start_s(w, 0, 0.0).to_bits()
            );
            assert_eq!(both.worker(w), solo_strag.worker(w));
        }
    }
}
