//! The scenario **Injector**: the runtime half of the scenario lab.
//!
//! PR 3's [`ScenarioModel`]s perturb the *simulator* (a seeded pass over
//! the [`FlowGraph`](crate::simcore::FlowGraph) before execution). This
//! module threads the same seeded draws into the **real** execution
//! path, so `train --scenario straggler --seed 7` replays the lifecycle
//! the planner evaluated: [`ThrottledStore`](crate::platform::ThrottledStore)
//! handles are scaled by per-worker bandwidth/latency multipliers, the
//! Function Manager's checkpoint/restart path (§3.1 step 8) charges a
//! scenario-scaled cold start per generation, and — because a scenario
//! run's whole point is replayable comparison — the function lifecycle
//! and the report's timeline run on a deterministic virtual clock
//! instead of the wall clock (see `coordinator::worker`).
//!
//! Determinism contract (mirrors `simcore::scenario`):
//! * every per-worker draw happens **strictly in worker-id order** at
//!   construction, from `util::rng` streams tagged with the same xor
//!   constants as the simulator — `cold-start` at seed 7 draws the
//!   *identical* generation-0 delays the simulator applies to its
//!   workers;
//! * per-*generation* cold-start draws (the simulator only ever sees
//!   generation 0) come from a stream keyed on `(worker, generation)`,
//!   so they are independent of thread interleaving;
//! * composite [`ScenarioSpec`]s apply components in canonical order,
//!   each from its own tagged stream, so composing never changes the
//!   draws a component would make alone.
//!
//! Real-path mapping of each lens (DESIGN.md §10): the simulator can
//! stretch a worker's compute, but the real path executes real
//! kernels, so a `straggler`'s compute factor maps onto its *storage*
//! path (bandwidth divided by, latency multiplied by the factor) and
//! onto the virtual clock; `bandwidth-jitter` draws one per-worker
//! lognormal transfer factor (the simulator draws per node — the
//! static per-worker form is the runtime analogue) plus the σ/3
//! compute factor; `cold-start` adds exponential delays to every
//! generation's cold start. Bandwidth multipliers only bite when the
//! run has a finite `throttle`; the lens never touches correctness,
//! only timing.

use crate::simcore::{
    cold_start_delays, straggler_factors, ScenarioModel, ScenarioSpec,
    BANDWIDTH_JITTER_TAG, COLD_START_TAG,
};
use crate::util::rng::Rng;

/// One worker's multiplicative lens on the real execution path.
/// Identity (`1.0` everywhere) under the deterministic scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerLens {
    /// Compute slowdown factor (≥ 1 under `straggler`): scales the
    /// virtual per-iteration time and, through `bandwidth_mult` /
    /// `latency_mult`, the worker's storage path.
    pub compute_mult: f64,
    /// Multiplies the worker's throttled uplink/downlink bandwidth
    /// (< 1 slows the worker).
    pub bandwidth_mult: f64,
    /// Multiplies the worker's per-access storage latency.
    pub latency_mult: f64,
}

impl WorkerLens {
    pub const IDENTITY: WorkerLens =
        WorkerLens { compute_mult: 1.0, bandwidth_mult: 1.0, latency_mult: 1.0 };
}

/// Seeded, deterministic perturbation provider for the real trainer.
#[derive(Debug, Clone)]
pub struct Injector {
    spec: ScenarioSpec,
    seed: u64,
    lenses: Vec<WorkerLens>,
    /// Generation-0 cold-start delays, drawn at construction from the
    /// simulator's exact stream (empty unless `cold-start` is active).
    cold_gen0: Vec<f64>,
    cold_mean_s: Option<f64>,
}

impl Injector {
    /// Draw every per-worker lens for `n_workers` workers (worker id =
    /// `stage * dp + replica`, the `FunctionInstance::launch` id), in
    /// worker-id order, component by component in canonical order.
    pub fn new(spec: &ScenarioSpec, seed: u64, n_workers: usize) -> Self {
        let mut lenses = vec![WorkerLens::IDENTITY; n_workers];
        let mut cold_gen0 = Vec::new();
        let mut cold_mean_s = None;
        for component in spec.components() {
            match *component {
                ScenarioModel::Deterministic => {}
                ScenarioModel::ColdStart { mean_s } => {
                    // the simulator's exact per-worker delay stream
                    cold_gen0 = cold_start_delays(seed, mean_s, n_workers);
                    cold_mean_s = Some(mean_s);
                }
                ScenarioModel::Straggler { prob, slowdown } => {
                    // the simulator's exact per-worker factor stream
                    let factors =
                        straggler_factors(seed, prob, slowdown, n_workers);
                    for (lens, factor) in lenses.iter_mut().zip(factors) {
                        lens.compute_mult *= factor;
                        lens.bandwidth_mult /= factor;
                        lens.latency_mult *= factor;
                    }
                }
                ScenarioModel::BandwidthJitter { sigma } => {
                    let mut rng = Rng::new(seed ^ BANDWIDTH_JITTER_TAG);
                    for lens in &mut lenses {
                        // lognormal around 1: a bandwidth dip stretches
                        // transfers by `t`, compute by the σ/3 factor
                        // (per worker — the runtime analogue of the
                        // simulator's per-node draws, same tagged
                        // stream)
                        let t = (sigma * rng.normal()).exp();
                        let c = (sigma / 3.0 * rng.normal()).exp();
                        lens.bandwidth_mult /= t;
                        lens.latency_mult *= t;
                        lens.compute_mult *= c;
                    }
                }
            }
        }
        Self { spec: spec.clone(), seed, lenses, cold_gen0, cold_mean_s }
    }

    /// An inactive injector (identity lenses, base cold starts only).
    pub fn inactive(n_workers: usize) -> Self {
        Self::new(&ScenarioSpec::deterministic(), 0, n_workers)
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn n_workers(&self) -> usize {
        self.lenses.len()
    }

    /// Whether any perturbation is active.
    pub fn is_active(&self) -> bool {
        !self.spec.is_deterministic()
    }

    pub fn worker(&self, worker: usize) -> WorkerLens {
        self.lenses.get(worker).copied().unwrap_or(WorkerLens::IDENTITY)
    }

    /// Seconds a cold start charges `worker` at `generation`: the
    /// platform/tier base plus, under `cold-start`, the exponential
    /// draw. Generation 0 uses the simulator's exact per-worker stream;
    /// later generations (which only the real path reaches) draw from a
    /// `(worker, generation)`-keyed stream so the value is independent
    /// of when other workers restart.
    pub fn cold_start_s(&self, worker: usize, generation: u32, base_s: f64) -> f64 {
        let extra = match self.cold_mean_s {
            None => 0.0,
            Some(mean_s) => {
                if generation == 0 {
                    self.cold_gen0.get(worker).copied().unwrap_or(0.0)
                } else {
                    let key = (worker as u64)
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ ((generation as u64) << 17)
                        ^ COLD_START_TAG;
                    Rng::new(self.seed ^ key).exponential(1.0 / mean_s)
                }
            }
        };
        base_s + extra
    }

    /// The worker's deterministic virtual per-iteration time given the
    /// scenario-free base (the plan's predicted `t_iter`, or a unit
    /// tick): the straggler/jitter compute factor stretches it.
    pub fn iter_virtual_s(&self, worker: usize, base_s: f64) -> f64 {
        base_s * self.worker(worker).compute_mult
    }

    /// The slowest worker's virtual per-iteration time — what gates a
    /// pipelined iteration end-to-end.
    pub fn max_iter_virtual_s(&self, base_s: f64) -> f64 {
        (0..self.lenses.len().max(1))
            .map(|w| self.iter_virtual_s(w, base_s))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{execute, FlowGraph, Node};

    fn spec(name: &str) -> ScenarioSpec {
        ScenarioSpec::parse(name).unwrap()
    }

    #[test]
    fn deterministic_is_identity() {
        let inj = Injector::inactive(4);
        assert!(!inj.is_active());
        for w in 0..4 {
            assert_eq!(inj.worker(w), WorkerLens::IDENTITY);
            assert_eq!(inj.cold_start_s(w, 0, 0.25), 0.25);
            assert_eq!(inj.cold_start_s(w, 3, 0.25), 0.25);
            assert_eq!(inj.iter_virtual_s(w, 2.0), 2.0);
        }
    }

    #[test]
    fn same_seed_replays_identically() {
        for name in
            ["cold-start", "straggler", "bandwidth-jitter", "cold-start+jitter"]
        {
            let a = Injector::new(&spec(name), 7, 6);
            let b = Injector::new(&spec(name), 7, 6);
            for w in 0..6 {
                assert_eq!(a.worker(w), b.worker(w), "{name} worker {w}");
                assert_eq!(
                    a.cold_start_s(w, 2, 0.1).to_bits(),
                    b.cold_start_s(w, 2, 0.1).to_bits()
                );
            }
        }
    }

    #[test]
    fn different_seeds_draw_differently() {
        for name in ["cold-start", "straggler", "bandwidth-jitter"] {
            let a = Injector::new(&spec(name), 1, 6);
            let b = Injector::new(&spec(name), 2, 6);
            let differs = (0..6).any(|w| {
                a.worker(w) != b.worker(w)
                    || a.cold_start_s(w, 0, 0.0) != b.cold_start_s(w, 0, 0.0)
            });
            assert!(differs, "{name}: seeds 1 and 2 drew identical lenses");
        }
    }

    #[test]
    fn cold_start_gen0_matches_the_simulator_stream() {
        // the injector's generation-0 delays must be the exact values
        // ScenarioModel::ColdStart applies to the simulator's workers
        let inj = Injector::new(&spec("cold-start"), 42, 3);
        let mut g = FlowGraph::new();
        for w in 0..3 {
            g.add(Node::compute(w, 1.0));
        }
        let base = execute(&g).makespan;
        ScenarioModel::parse("cold-start").unwrap().apply(&mut g, 42);
        let max_delay = (0..3)
            .map(|w| inj.cold_start_s(w, 0, 0.0))
            .fold(0.0, f64::max);
        assert!(max_delay > 0.0);
        // the delays are continuous draws: a mismatched stream would be
        // off by ~seconds, not float-stepping noise
        let makespan = execute(&g).makespan;
        assert!(
            (makespan - (base + max_delay)).abs() < 1e-9,
            "sim cold-start delays diverge from the injector's: \
             {makespan} vs {}",
            base + max_delay
        );
    }

    #[test]
    fn straggler_lens_matches_sim_parameterization() {
        let inj = Injector::new(&spec("straggler"), 5, 8);
        for w in 0..8 {
            let lens = inj.worker(w);
            // factors live in the sim's [1.0, slowdown] band and the
            // bandwidth/latency mapping is the factor's reciprocal/value
            assert!(lens.compute_mult >= 1.0 && lens.compute_mult <= 2.5);
            assert!((lens.bandwidth_mult - 1.0 / lens.compute_mult).abs() < 1e-12);
            assert!((lens.latency_mult - lens.compute_mult).abs() < 1e-12);
        }
        // background factors make every pair of seeds differ a.s.
        assert!(inj.max_iter_virtual_s(1.0) > 1.0);
    }

    #[test]
    fn straggler_lens_matches_the_simulator_factors() {
        // the lens multipliers must be exactly the factors the simulator
        // multiplies compute work by — shared stream, shared discipline
        let inj = Injector::new(&spec("straggler"), 11, 5);
        let factors = crate::simcore::straggler_factors(11, 0.2, 2.5, 5);
        for (w, f) in factors.iter().enumerate() {
            assert_eq!(inj.worker(w).compute_mult.to_bits(), f.to_bits());
        }
    }

    #[test]
    fn per_generation_cold_draws_are_keyed_and_distinct() {
        let inj = Injector::new(&spec("cold-start"), 9, 2);
        let g1 = inj.cold_start_s(0, 1, 0.0);
        let g2 = inj.cold_start_s(0, 2, 0.0);
        assert!(g1 > 0.0 && g2 > 0.0);
        assert_ne!(g1.to_bits(), g2.to_bits());
        // distinct workers draw independently at the same generation
        assert_ne!(
            inj.cold_start_s(0, 1, 0.0).to_bits(),
            inj.cold_start_s(1, 1, 0.0).to_bits()
        );
        // and the base is always charged on top
        assert_eq!(inj.cold_start_s(0, 1, 1.5), 1.5 + g1);
    }

    #[test]
    fn composite_components_draw_their_solo_streams() {
        let solo_cold = Injector::new(&spec("cold-start"), 7, 4);
        let solo_strag = Injector::new(&spec("straggler"), 7, 4);
        let both = Injector::new(&spec("cold-start+straggler"), 7, 4);
        for w in 0..4 {
            assert_eq!(
                both.cold_start_s(w, 0, 0.0).to_bits(),
                solo_cold.cold_start_s(w, 0, 0.0).to_bits()
            );
            assert_eq!(both.worker(w), solo_strag.worker(w));
        }
    }
}
