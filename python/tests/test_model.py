"""L2 model correctness: staged fwd/bwd == monolithic jax.grad."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.model import (
    ModelConfig,
    build_stages,
    full_forward_loss,
    merge_two,
    sgd_step,
    staged_backward,
)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, d_ff=64, seq_len=16,
                  n_layers=2, n_block_stages=2, micro_batch=2)


@pytest.fixture(scope="module")
def params():
    rng = jax.random.PRNGKey(0)
    out = []
    for stage in build_stages(CFG):
        rng, sub = jax.random.split(rng)
        out.append(stage.init(sub))
    return out


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (CFG.micro_batch, CFG.seq_len), 0,
                                CFG.vocab)
    targets = jax.random.randint(k2, (CFG.micro_batch, CFG.seq_len), 0,
                                 CFG.vocab)
    return tokens, targets


def test_stage_shapes(params):
    stages = build_stages(CFG)
    assert len(stages) == CFG.n_stages
    for stage, p in zip(stages, params):
        assert len(p) == len(stage.param_specs)
        for arr, (_, shape) in zip(p, stage.param_specs):
            assert arr.shape == shape


def test_loss_is_finite_and_near_uniform(params, batch):
    tokens, targets = batch
    loss = full_forward_loss(CFG, params, tokens, targets)
    assert np.isfinite(float(loss))
    # ~ln(vocab) at init (uniform predictions)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_staged_backward_matches_monolithic_grad(params, batch):
    """The stage-by-stage vjp chain (what the rust pipeline executes) must
    equal jax.grad of the composed model."""
    tokens, targets = batch
    loss_staged, grads_staged = staged_backward(CFG, params, tokens, targets)

    def mono(all_params):
        return full_forward_loss(CFG, all_params, tokens, targets)

    loss_mono = mono(params)
    grads_mono = jax.grad(mono)(params)
    assert_allclose(float(loss_staged), float(loss_mono), rtol=1e-5)
    for gs, gm in zip(grads_staged, grads_mono):
        for a, b in zip(gs, gm):
            assert_allclose(np.asarray(a), np.asarray(b),
                            rtol=5e-4, atol=5e-4)


def test_blocks_stage_bwd_is_vjp(params, batch):
    """Block-stage bwd with an arbitrary cotangent equals direct vjp."""
    stages = build_stages(CFG)
    s, p = stages[1], params[1]
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (CFG.micro_batch, CFG.seq_len, CFG.d_model))
    gy = jax.random.normal(jax.random.PRNGKey(4), x.shape)
    grads, gx = s.bwd(p, x, gy)
    _, vjp = jax.vjp(s.fwd, p, x)
    grads_ref, gx_ref = vjp(gy)
    assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-5, atol=1e-5)
    for a, b in zip(grads, grads_ref):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_sgd_step_matches_manual(params):
    p = params[-1]
    g = [jnp.ones_like(t) for t in p]
    lr = jnp.float32(0.05)
    new = sgd_step(p, g, lr)
    for old, upd in zip(p, new):
        assert_allclose(np.asarray(upd), np.asarray(old) - 0.05,
                        rtol=1e-6, atol=1e-6)


def test_merge_two_is_addition():
    a = jax.random.normal(jax.random.PRNGKey(5), (1000,))
    b = jax.random.normal(jax.random.PRNGKey(6), (1000,))
    assert_allclose(np.asarray(merge_two(a, b)), np.asarray(a + b),
                    rtol=1e-6, atol=1e-6)


def test_training_reduces_loss(params, batch):
    """A few SGD steps on a fixed batch must reduce the loss — the whole
    point of the composed fwd/bwd/sgd artifacts."""
    tokens, targets = batch
    cur = [list(p) for p in params]
    losses = []
    for _ in range(5):
        loss, grads = staged_backward(CFG, cur, tokens, targets)
        losses.append(float(loss))
        cur = [sgd_step(p, g, jnp.float32(0.5)) for p, g in zip(cur, grads)]
    final_loss, _ = staged_backward(CFG, cur, tokens, targets)
    losses.append(float(final_loss))
    assert losses[-1] < losses[0], losses


def test_param_count_consistency():
    cfg = CFG
    total = cfg.param_count()
    by_stage = sum(s.flat_param_size for s in build_stages(cfg))
    assert total == by_stage
    # embed: V*D + T*D ; head: 2D + D*V + V
    embed = cfg.vocab * cfg.d_model + cfg.seq_len * cfg.d_model
    head = 2 * cfg.d_model + cfg.d_model * cfg.vocab + cfg.vocab
    assert build_stages(cfg)[0].flat_param_size == embed
    assert build_stages(cfg)[-1].flat_param_size == head


def test_config_validation():
    with pytest.raises(AssertionError):
        ModelConfig(d_model=30, n_heads=4)
    with pytest.raises(AssertionError):
        ModelConfig(n_layers=3, n_block_stages=2)


def test_larger_single_block_stage():
    cfg = dataclasses.replace(CFG, n_block_stages=1, n_layers=2)
    stages = build_stages(cfg)
    assert len(stages) == 3
    rng = jax.random.PRNGKey(0)
    p = stages[1].init(rng)
    x = jax.random.normal(rng, (cfg.micro_batch, cfg.seq_len, cfg.d_model))
    y = stages[1].fwd(p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
