"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/blocks/dtypes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.fused_linear import (
    fused_linear,
    fused_linear_ad,
    fused_linear_noscratch,
    vmem_bytes,
)
from compile.kernels.grad_merge import grad_merge, sgd_apply


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
@pytest.mark.parametrize("impl", [fused_linear, fused_linear_noscratch])
def test_fused_linear_matches_ref(activation, impl):
    x, w, b = _rand(0, (64, 96)), _rand(1, (96, 48)), _rand(2, (48,))
    y = impl(x, w, b, activation=activation, bm=32, bn=16, bk=32)
    assert_allclose(
        np.asarray(y),
        np.asarray(ref.fused_linear_ref(x, w, b, activation)),
        rtol=1e-5, atol=1e-5,
    )


@settings(max_examples=25, deadline=None)
@given(
    bm_pow=st.integers(2, 5),
    bn_pow=st.integers(2, 5),
    bk_pow=st.integers(2, 5),
    m_mult=st.integers(1, 3),
    n_mult=st.integers(1, 3),
    k_mult=st.integers(1, 3),
    activation=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**16),
)
def test_fused_linear_shape_block_sweep(
    bm_pow, bn_pow, bk_pow, m_mult, n_mult, k_mult, activation, seed
):
    """Property: for every valid (shape, block) combination the tiled kernel
    is numerically identical to the untiled reference."""
    bm, bn, bk = 2**bm_pow, 2**bn_pow, 2**bk_pow
    m, n, k = bm * m_mult, bn * n_mult, bk * k_mult
    key = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    y = fused_linear_noscratch(x, w, b, activation=activation,
                               bm=bm, bn=bn, bk=bk)
    assert_allclose(
        np.asarray(y),
        np.asarray(ref.fused_linear_ref(x, w, b, activation)),
        rtol=2e-4, atol=2e-4,
    )


def test_fused_linear_default_blocks_nondivisible_dims():
    """_pick_block must find exact divisors for awkward sizes."""
    x, w, b = _rand(3, (12, 20)), _rand(4, (20, 28)), _rand(5, (28,))
    y = fused_linear_noscratch(x, w, b, activation="gelu")
    assert_allclose(np.asarray(y),
                    np.asarray(ref.fused_linear_ref(x, w, b, "gelu")),
                    rtol=1e-5, atol=1e-5)


def test_fused_linear_rejects_bad_blocks():
    x, w, b = _rand(0, (64, 64)), _rand(1, (64, 64)), _rand(2, (64,))
    with pytest.raises(AssertionError):
        fused_linear_noscratch(x, w, b, bm=48, bn=64, bk=64)


@pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
def test_fused_linear_ad_gradients(activation):
    """custom_vjp backward == jax.grad of the pure-jnp reference."""
    x, w, b = _rand(7, (32, 48)), _rand(8, (48, 16)), _rand(9, (16,))

    def loss_kernel(x, w, b):
        return jnp.sum(fused_linear_ad(x, w, b, activation) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, activation) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(r), rtol=5e-4, atol=5e-4)


def test_vmem_budget_mxu_tiles():
    """The default MXU-aligned tiling fits a 16 MiB VMEM with double
    buffering — the DESIGN.md roofline claim."""
    assert vmem_bytes(128, 128, 128) <= 16 * 1024 * 1024
    # and the largest tile that still fits:
    assert vmem_bytes(256, 256, 512) <= 16 * 1024 * 1024
    assert vmem_bytes(1024, 1024, 1024) > 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# grad_merge / sgd_apply
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 8),
    n_blocks=st.integers(1, 4),
    bn=st.sampled_from([64, 256, 1024]),
    average=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_grad_merge_sweep(k, n_blocks, bn, average, seed):
    n = bn * n_blocks
    s = jax.random.normal(jax.random.PRNGKey(seed), (k, n), jnp.float32)
    got = grad_merge(s, bn=bn, average=average)
    want = ref.grad_merge_ref(s, average=average)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_grad_merge_odd_length():
    s = _rand(11, (3, 999))
    assert_allclose(np.asarray(grad_merge(s)),
                    np.asarray(ref.grad_merge_ref(s)), rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([128, 1000, 4096, 5000]),
    lr=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**16),
)
def test_sgd_apply_sweep(n, lr, seed):
    kp, kg = jax.random.split(jax.random.PRNGKey(seed))
    p = jax.random.normal(kp, (n,), jnp.float32)
    g = jax.random.normal(kg, (n,), jnp.float32)
    got = sgd_apply(p, g, jnp.float32(lr))
    assert_allclose(np.asarray(got),
                    np.asarray(ref.sgd_apply_ref(p, g, jnp.float32(lr))),
                    rtol=1e-6, atol=1e-6)


def test_grad_merge_is_linear():
    """Merge(a) + Merge(b) == Merge(a + b) — linearity invariant the
    scatter-reduce algorithms rely on for split/merge order independence."""
    a, b = _rand(20, (4, 512)), _rand(21, (4, 512))
    lhs = grad_merge(a) + grad_merge(b)
    rhs = grad_merge(a + b)
    assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-5)
