"""AOT artifact validation: lowered HLO text executes (via jax's own CPU
client) and matches the eager stage functions; manifest is consistent."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.aot import to_hlo_text
from compile.model import ModelConfig, build_stages

CFG = ModelConfig(vocab=32, d_model=16, n_heads=2, d_ff=32, seq_len=8,
                  n_layers=2, n_block_stages=1, micro_batch=2)


def test_hlo_text_is_parseable_module():
    stage = build_stages(CFG)[1]
    p = stage.init(jax.random.PRNGKey(0))

    def fwd_flat(*args):
        return (stage.fwd(list(args[:-1]), args[-1]),)

    sds = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in p]
    x_sds = jax.ShapeDtypeStruct(
        (CFG.micro_batch, CFG.seq_len, CFG.d_model), jnp.float32)
    text = to_hlo_text(jax.jit(fwd_flat).lower(*sds, x_sds))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => root is a tuple
    assert "parameter(0)" in text


def test_aot_cli_writes_all_artifacts():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d,
             "--vocab", "32", "--d-model", "16", "--n-heads", "2",
             "--d-ff", "32", "--seq-len", "8", "--n-layers", "2",
             "--n-block-stages", "1", "--micro-batch", "2"],
            cwd=repo_py, env=env, check=True, capture_output=True,
        )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["n_stages"] == 3
        for entry in manifest["stages"]:
            for key in ("fwd", "bwd", "sgd", "merge2", "init"):
                path = os.path.join(d, entry["files"][key])
                assert os.path.exists(path), path
            init_size = os.path.getsize(
                os.path.join(d, entry["files"]["init"]))
            assert init_size == 4 * entry["flat_param_size"]
            assert entry["flat_param_size"] == sum(
                p["numel"] for p in entry["params"])


def test_manifest_matches_model_config():
    with tempfile.TemporaryDirectory() as d:
        repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d,
             "--vocab", "32", "--d-model", "16", "--n-heads", "2",
             "--d-ff", "32", "--seq-len", "8", "--n-layers", "2",
             "--n-block-stages", "1", "--micro-batch", "2"],
            cwd=repo_py, check=True, capture_output=True,
        )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["total_params"] == CFG.param_count()
        stages = build_stages(CFG)
        for entry, stage in zip(manifest["stages"], stages):
            assert entry["name"] == stage.name
            assert entry["kind"] == stage.kind
            assert tuple(entry["input_shape"]) == stage.input_shape
            assert tuple(entry["output_shape"]) == stage.output_shape


def test_roundtrip_numerics_through_hlo():
    """Compile the lowered stablehlo with jax's CPU client and compare with
    the eager stage — the same HLO text the rust runtime will execute."""
    stage = build_stages(CFG)[2]  # head
    p = stage.init(jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (CFG.micro_batch, CFG.seq_len, CFG.d_model))
    targets = jax.random.randint(jax.random.PRNGKey(3),
                                 (CFG.micro_batch, CFG.seq_len), 0, CFG.vocab)

    def fwd_flat(*args):
        return (stage.fwd(list(args[:4]), args[4], args[5]),)

    jitted = jax.jit(fwd_flat)
    eager = fwd_flat(*p, x, targets)[0]
    compiled = jitted(*p, x, targets)[0]
    assert_allclose(float(compiled), float(eager), rtol=1e-5)
