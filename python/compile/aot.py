"""AOT lowering: JAX stages -> HLO *text* artifacts for the rust runtime.

Python runs exactly once (``make artifacts``); after that the rust binary is
self-contained. Interchange is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per pipeline stage we emit:
  stage<i>_fwd.hlo.txt    fwd(params..., x[, targets]) -> (y,)
  stage<i>_bwd.hlo.txt    bwd(params..., x[, targets][, gy])
                              -> (grads..., gx[, loss])
  stage<i>_sgd.hlo.txt    sgd(params..., grads..., lr) -> (params'...)
  stage<i>_merge2.hlo.txt merge(a_flat, b_flat) -> (sum,)   [pallas kernel]

plus ``manifest.json`` describing every artifact: parameter layout (name,
shape, element count, byte offsets in flattening order), I/O shapes and the
argument order of each entry point — everything the rust loader
(`runtime/artifact.rs`) needs to drive the executables without touching
python.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, StageSpec, build_stages, merge_two, sgd_step


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def kept_args(lowered) -> list:
    """Indices of entry arguments the lowering kept.

    jax.jit prunes arguments that do not influence the outputs (e.g. a
    bias whose VJP needs only the cotangent); the rust runtime must feed
    exactly the kept ones, so the manifest records this mapping.
    """
    idx = lowered._lowering.compile_args.get("kept_var_idx")
    return sorted(idx)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_sds(stage: StageSpec) -> List[jax.ShapeDtypeStruct]:
    return [_sds(shape) for _, shape in stage.param_specs]


def _input_sds(stage: StageSpec) -> jax.ShapeDtypeStruct:
    dt = jnp.int32 if stage.input_dtype == "i32" else jnp.float32
    return _sds(stage.input_shape, dt)


def lower_stage(stage: StageSpec, cfg: ModelConfig, out_dir: str,
                idx: int) -> dict:
    """Lower fwd/bwd/sgd/merge2 for one stage; return its manifest entry."""
    n_params = len(stage.param_specs)
    p_sds = _param_sds(stage)
    x_sds = _input_sds(stage)
    B, T = cfg.micro_batch, cfg.seq_len
    tgt_sds = _sds((B, T), jnp.int32)
    gy_sds = _sds(stage.output_shape)

    files = {}

    # ---- forward -----------------------------------------------------
    if stage.kind == "head":
        def fwd_flat(*args):
            params = list(args[:n_params])
            x, targets = args[n_params], args[n_params + 1]
            return (stage.fwd(params, x, targets),)
        fwd_args = p_sds + [x_sds, tgt_sds]
    else:
        def fwd_flat(*args):
            params = list(args[:n_params])
            x = args[n_params]
            return (stage.fwd(params, x),)
        fwd_args = p_sds + [x_sds]
    kept = {}
    files["fwd"] = f"stage{idx}_fwd.hlo.txt"
    lowered = jax.jit(fwd_flat).lower(*fwd_args)
    kept["fwd"] = kept_args(lowered)
    _write(out_dir, files["fwd"], to_hlo_text(lowered))

    # ---- backward ----------------------------------------------------
    if stage.kind == "head":
        def bwd_flat(*args):
            params = list(args[:n_params])
            x, targets = args[n_params], args[n_params + 1]
            grads, gx, loss = stage.bwd(params, x, targets)
            return tuple(grads) + (gx, loss)
        bwd_args = p_sds + [x_sds, tgt_sds]
    elif stage.kind == "embed":
        def bwd_flat(*args):
            params = list(args[:n_params])
            x, gy = args[n_params], args[n_params + 1]
            grads, _ = stage.bwd(params, x, gy)
            return tuple(grads)
        bwd_args = p_sds + [x_sds, gy_sds]
    else:
        def bwd_flat(*args):
            params = list(args[:n_params])
            x, gy = args[n_params], args[n_params + 1]
            grads, gx = stage.bwd(params, x, gy)
            return tuple(grads) + (gx,)
        bwd_args = p_sds + [x_sds, gy_sds]
    files["bwd"] = f"stage{idx}_bwd.hlo.txt"
    lowered = jax.jit(bwd_flat).lower(*bwd_args)
    kept["bwd"] = kept_args(lowered)
    _write(out_dir, files["bwd"], to_hlo_text(lowered))

    # ---- sgd update ----------------------------------------------------
    def sgd_flat(*args):
        params = list(args[:n_params])
        grads = list(args[n_params:2 * n_params])
        lr = args[2 * n_params]
        return tuple(sgd_step(params, grads, lr))
    files["sgd"] = f"stage{idx}_sgd.hlo.txt"
    lowered = jax.jit(sgd_flat).lower(*(p_sds + p_sds + [_sds(())]))
    kept["sgd"] = kept_args(lowered)
    _write(out_dir, files["sgd"], to_hlo_text(lowered))

    # ---- pairwise gradient merge (scatter-reduce inner op) -------------
    flat = stage.flat_param_size
    def merge_flat(a, b):
        return (merge_two(a, b),)
    files["merge2"] = f"stage{idx}_merge2.hlo.txt"
    lowered = jax.jit(merge_flat).lower(_sds((flat,)), _sds((flat,)))
    kept["merge2"] = kept_args(lowered)
    _write(out_dir, files["merge2"], to_hlo_text(lowered))

    return {
        "index": idx,
        "name": stage.name,
        "kind": stage.kind,
        "params": [
            {"name": n, "shape": list(s), "numel": _numel(s)}
            for n, s in stage.param_specs
        ],
        "flat_param_size": flat,
        "input_shape": list(stage.input_shape),
        "input_dtype": stage.input_dtype,
        "output_shape": list(stage.output_shape),
        "files": files,
        "kept_args": kept,
    }


def _numel(shape: Sequence[int]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def _write(out_dir: str, name: str, text: str) -> None:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text)//1024} KiB)")


def dump_init_params(cfg: ModelConfig, out_dir: str, seed: int) -> List[str]:
    """Serialize deterministic initial parameters as raw little-endian f32.

    One file per stage, tensors concatenated in param_specs order; the rust
    loader slices them back out using the manifest offsets.
    """
    import numpy as np

    names = []
    rng = jax.random.PRNGKey(seed)
    for idx, stage in enumerate(build_stages(cfg)):
        rng, sub = jax.random.split(rng)
        params = stage.init(sub)
        flat = np.concatenate(
            [np.asarray(p, dtype=np.float32).reshape(-1) for p in params]
        )
        name = f"stage{idx}_init.f32"
        flat.tofile(os.path.join(out_dir, name))
        names.append(name)
        print(f"  wrote {name} ({flat.nbytes//1024} KiB)")
    return names


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) ignored if --out-dir given")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--n-block-stages", type=int, default=2)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None and args.out_dir == "../artifacts":
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    cfg = ModelConfig(
        vocab=args.vocab, d_model=args.d_model, n_heads=args.n_heads,
        d_ff=args.d_ff, seq_len=args.seq_len, n_layers=args.n_layers,
        n_block_stages=args.n_block_stages, micro_batch=args.micro_batch,
    )
    stages = build_stages(cfg)
    print(f"lowering {len(stages)} stages "
          f"({cfg.param_count()/1e6:.2f}M params) -> {out_dir}")

    entries = [lower_stage(s, cfg, out_dir, i) for i, s in enumerate(stages)]
    inits = dump_init_params(cfg, out_dir, args.seed)
    for e, init_name in zip(entries, inits):
        e["files"]["init"] = init_name

    manifest = {
        "format_version": 1,
        "config": dataclasses.asdict(cfg),
        "n_stages": len(stages),
        "total_params": cfg.param_count(),
        "stages": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(stages)} stages)")


if __name__ == "__main__":
    main()
