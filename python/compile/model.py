"""L2: the JAX model — a GPT-style transformer LM split into pipeline stages.

FuncPipe partitions a layered model across serverless workers (§3.2). Here
the model is expressed as an explicit list of *stages*, each with its own
parameter list and pure `fwd` / `bwd` functions, so that `aot.py` can lower
every stage to a standalone HLO-text executable that the rust coordinator
places on a worker:

  stage 0        : embedding       (tokens  -> hidden)
  stage 1..G     : transformer-block groups (hidden -> hidden)
  stage G+1      : head            (hidden, targets -> scalar loss)

Backward functions use `jax.vjp` over the stage forward, i.e. activations
are *rematerialized* inside the stage (GPipe-style): a worker only ever
stores the stage input it received from storage, never interior
activations, matching the paper's memory model (constraint (3b)).

The MLP inside each block calls the L1 Pallas kernel
(`kernels.fused_linear`), so the kernel lowers into the same HLO the rust
runtime executes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear_ad
from .kernels.grad_merge import grad_merge, sgd_apply


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters for the staged transformer."""

    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 32
    n_layers: int = 2
    n_block_stages: int = 2  # how many stages the blocks are grouped into
    micro_batch: int = 4

    def __post_init__(self):
        assert self.d_model % self.n_heads == 0
        assert self.n_layers % self.n_block_stages == 0

    @property
    def layers_per_stage(self) -> int:
        return self.n_layers // self.n_block_stages

    @property
    def n_stages(self) -> int:
        return self.n_block_stages + 2

    def param_count(self) -> int:
        total = 0
        for stage in build_stages(self):
            total += stage.flat_param_size
        return total


ParamSpecs = List[Tuple[str, Tuple[int, ...]]]
Params = List[jax.Array]


def _numel(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


@dataclasses.dataclass
class StageSpec:
    """One pipeline stage: parameter layout + pure fwd/bwd callables.

    fwd(params, x[, targets]) -> y (or scalar loss for the head)
    bwd(params, x[, targets], gy) -> (grads, gx)  — head returns loss too.
    """

    name: str
    kind: str  # "embed" | "blocks" | "head"
    param_specs: ParamSpecs
    init: Callable[[jax.Array], Params]
    fwd: Callable[..., jax.Array]
    bwd: Callable[..., Tuple]
    # static I/O shapes (per micro-batch), used by aot.py + the manifest
    input_shape: Tuple[int, ...] = ()
    input_dtype: str = "f32"
    output_shape: Tuple[int, ...] = ()

    @property
    def flat_param_size(self) -> int:
        return sum(_numel(s) for _, s in self.param_specs)


# ---------------------------------------------------------------------------
# layer math
# ---------------------------------------------------------------------------


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(x: jax.Array, wq, bq, wk, bk, wv, bv, wo, bo,
               n_heads: int) -> jax.Array:
    """Causal multi-head self-attention. x: (B, T, D)."""
    B, T, D = x.shape
    H = n_heads
    Dh = D // H

    def proj(w, b):
        return (x @ w + b).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)

    q, k, v = proj(wq, bq), proj(wk, bk), proj(wv, bv)
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(Dh).astype(x.dtype)
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, jnp.finfo(x.dtype).min)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ wo + bo


def _mlp(x: jax.Array, w1, b1, w2, b2) -> jax.Array:
    """Transformer MLP on the L1 Pallas kernel (the compute hot-spot)."""
    B, T, D = x.shape
    flat = x.reshape(B * T, D)
    h = fused_linear_ad(flat, w1, b1, "gelu")
    y = fused_linear_ad(h, w2, b2, "none")
    return y.reshape(B, T, D)


def _block(x: jax.Array, p: Dict[str, jax.Array], n_heads: int) -> jax.Array:
    h = x + _attention(
        _layer_norm(x, p["ln1_g"], p["ln1_b"]),
        p["wq"], p["bq"], p["wk"], p["bk"], p["wv"], p["bv"],
        p["wo"], p["bo"], n_heads,
    )
    h = h + _mlp(
        _layer_norm(h, p["ln2_g"], p["ln2_b"]),
        p["w1"], p["b1"], p["w2"], p["b2"],
    )
    return h


_BLOCK_PARAM_NAMES = [
    "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
]


def _block_param_specs(cfg: ModelConfig, prefix: str) -> ParamSpecs:
    D, F = cfg.d_model, cfg.d_ff
    shapes = {
        "ln1_g": (D,), "ln1_b": (D,),
        "wq": (D, D), "bq": (D,), "wk": (D, D), "bk": (D,),
        "wv": (D, D), "bv": (D,), "wo": (D, D), "bo": (D,),
        "ln2_g": (D,), "ln2_b": (D,),
        "w1": (D, F), "b1": (F,), "w2": (F, D), "b2": (D,),
    }
    return [(f"{prefix}.{n}", shapes[n]) for n in _BLOCK_PARAM_NAMES]


def _init_from_specs(specs: ParamSpecs, rng: jax.Array) -> Params:
    params = []
    keys = jax.random.split(rng, len(specs))
    for (name, shape), key in zip(specs, keys):
        base = name.rsplit(".", 1)[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            params.append(
                0.02 * jax.random.normal(key, shape, jnp.float32)
            )
    return params


# ---------------------------------------------------------------------------
# stage builders
# ---------------------------------------------------------------------------


def _embed_stage(cfg: ModelConfig) -> StageSpec:
    B, T, D, V = cfg.micro_batch, cfg.seq_len, cfg.d_model, cfg.vocab
    specs: ParamSpecs = [("tok_emb", (V, D)), ("pos_emb", (T, D))]

    def fwd(params: Params, tokens: jax.Array) -> jax.Array:
        tok_emb, pos_emb = params
        return tok_emb[tokens] + pos_emb[None, :, :]

    def bwd(params: Params, tokens: jax.Array, gh: jax.Array):
        _, vjp = jax.vjp(lambda p: fwd(p, tokens), params)
        (grads,) = vjp(gh)
        return grads, jnp.zeros((), jnp.float32)  # no upstream gx

    def init(rng: jax.Array) -> Params:
        k1, k2 = jax.random.split(rng)
        return [
            0.02 * jax.random.normal(k1, (V, D), jnp.float32),
            0.01 * jax.random.normal(k2, (T, D), jnp.float32),
        ]

    return StageSpec(
        name="embed", kind="embed", param_specs=specs, init=init,
        fwd=fwd, bwd=bwd,
        input_shape=(B, T), input_dtype="i32", output_shape=(B, T, D),
    )


def _blocks_stage(cfg: ModelConfig, idx: int) -> StageSpec:
    B, T, D = cfg.micro_batch, cfg.seq_len, cfg.d_model
    nl = cfg.layers_per_stage
    specs: ParamSpecs = []
    for l in range(nl):
        specs += _block_param_specs(cfg, f"l{l}")
    per_block = len(_BLOCK_PARAM_NAMES)

    def fwd(params: Params, x: jax.Array) -> jax.Array:
        h = x
        for l in range(nl):
            chunk = params[l * per_block:(l + 1) * per_block]
            p = dict(zip(_BLOCK_PARAM_NAMES, chunk))
            h = _block(h, p, cfg.n_heads)
        return h

    def bwd(params: Params, x: jax.Array, gy: jax.Array):
        _, vjp = jax.vjp(fwd, params, x)
        grads, gx = vjp(gy)
        return grads, gx

    def init(rng: jax.Array) -> Params:
        return _init_from_specs(specs, rng)

    return StageSpec(
        name=f"blocks{idx}", kind="blocks", param_specs=specs, init=init,
        fwd=fwd, bwd=bwd,
        input_shape=(B, T, D), output_shape=(B, T, D),
    )


def _head_stage(cfg: ModelConfig) -> StageSpec:
    B, T, D, V = cfg.micro_batch, cfg.seq_len, cfg.d_model, cfg.vocab
    specs: ParamSpecs = [
        ("lnf_g", (D,)), ("lnf_b", (D,)), ("w_out", (D, V)), ("b_out", (V,)),
    ]

    def fwd(params: Params, x: jax.Array, targets: jax.Array) -> jax.Array:
        lnf_g, lnf_b, w_out, b_out = params
        h = _layer_norm(x, lnf_g, lnf_b)
        logits = h @ w_out + b_out  # (B, T, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    def bwd(params: Params, x: jax.Array, targets: jax.Array):
        loss, vjp = jax.vjp(lambda p, xx: fwd(p, xx, targets), params, x)
        grads, gx = vjp(jnp.ones((), jnp.float32))
        return grads, gx, loss

    def init(rng: jax.Array) -> Params:
        k1, _ = jax.random.split(rng)
        return [
            jnp.ones((D,), jnp.float32),
            jnp.zeros((D,), jnp.float32),
            0.02 * jax.random.normal(k1, (D, V), jnp.float32),
            jnp.zeros((V,), jnp.float32),
        ]

    return StageSpec(
        name="head", kind="head", param_specs=specs, init=init,
        fwd=fwd, bwd=bwd,
        input_shape=(B, T, D), output_shape=(),
    )


def build_stages(cfg: ModelConfig) -> List[StageSpec]:
    """All pipeline stages of the model, in order."""
    stages = [_embed_stage(cfg)]
    stages += [_blocks_stage(cfg, i) for i in range(cfg.n_block_stages)]
    stages.append(_head_stage(cfg))
    return stages


# ---------------------------------------------------------------------------
# reference full-model step (for python tests: stage-composed == monolithic)
# ---------------------------------------------------------------------------


def full_forward_loss(cfg: ModelConfig, all_params: List[Params],
                      tokens: jax.Array, targets: jax.Array) -> jax.Array:
    """Monolithic forward pass composing all stages (oracle for tests)."""
    stages = build_stages(cfg)
    h = stages[0].fwd(all_params[0], tokens)
    for s, p in zip(stages[1:-1], all_params[1:-1]):
        h = s.fwd(p, h)
    return stages[-1].fwd(all_params[-1], h, targets)


def staged_backward(cfg: ModelConfig, all_params: List[Params],
                    tokens: jax.Array, targets: jax.Array):
    """Runs the staged fwd+bwd exactly as the rust pipeline will.

    Returns (loss, grads per stage). Used as the test oracle that the
    stage-wise vjp chaining reproduces jax.grad of the monolithic model.
    """
    stages = build_stages(cfg)
    acts = [None] * len(stages)  # stage inputs
    acts[0] = tokens
    h = stages[0].fwd(all_params[0], tokens)
    for i, (s, p) in enumerate(zip(stages[1:-1], all_params[1:-1]), start=1):
        acts[i] = h
        h = s.fwd(p, h)
    acts[-1] = h

    grads = [None] * len(stages)
    grads[-1], gx, loss = stages[-1].bwd(all_params[-1], acts[-1], targets)
    for i in range(len(stages) - 2, 0, -1):
        grads[i], gx = stages[i].bwd(all_params[i], acts[i], gx)
    grads[0], _ = stages[0].bwd(all_params[0], tokens, gx)
    return loss, grads


# ---------------------------------------------------------------------------
# stage-level auxiliary computations lowered by aot.py
# ---------------------------------------------------------------------------


def sgd_step(params: Params, grads: Params, lr: jax.Array) -> Params:
    """p <- p - lr*g per tensor, through the L1 sgd_apply kernel."""
    out = []
    for p, g in zip(params, grads):
        flat = sgd_apply(p.reshape(-1), g.reshape(-1), lr)
        out.append(flat.reshape(p.shape))
    return out


def merge_two(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two flattened gradient splits (scatter-reduce inner op)."""
    return grad_merge(jnp.stack([a, b]), average=False)
