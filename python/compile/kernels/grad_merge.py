"""L1 Pallas kernel: k-way gradient-split merge for scatter-reduce.

In FuncPipe's (pipelined) scatter-reduce, worker i is responsible for
reducing split i of the flattened gradient vector across the d data-parallel
replicas of its stage (§3.3). The reduction itself is the compute half of
the sync step; this kernel performs it as a tiled sum over a (k, n) stack of
gradient splits, streaming BN-sized column blocks through VMEM.

Memory-bound by design: arithmetic intensity is (k-1)/k adds per element, so
the right schedule is a single pass with wide vector tiles — expressed here
with a 1-D grid over n and the k axis kept resident per tile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 4096


def _merge_kernel(splits_ref, o_ref, *, scale: float):
    # splits_ref: (k, BN) tile; sum over k with f32 accumulation.
    acc = jnp.sum(splits_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = (acc * scale).astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("bn", "average"))
def grad_merge(
    splits: jax.Array,
    bn: Optional[int] = None,
    average: bool = False,
) -> jax.Array:
    """Sum (or average) k gradient splits: (k, n) -> (n,)."""
    k, n = splits.shape
    bn = bn or _pick_block(n, DEFAULT_BN)
    assert n % bn == 0, f"n={n} not divisible by block {bn}"
    scale = 1.0 / k if average else 1.0
    kernel = functools.partial(_merge_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), splits.dtype),
        interpret=True,
    )(splits)


@functools.partial(jax.jit, static_argnames=("bn",))
def sgd_apply(params: jax.Array, grads: jax.Array, lr: jax.Array,
              bn: Optional[int] = None) -> jax.Array:
    """Fused SGD update on a flattened parameter vector: p - lr*g.

    Tiled the same way as grad_merge (memory-bound single pass). Used by the
    rust trainer's weight-update executable.
    """
    (n,) = params.shape
    assert grads.shape == (n,)
    bn = bn or _pick_block(n, DEFAULT_BN)
    assert n % bn == 0

    def kernel(p_ref, g_ref, lr_ref, o_ref):
        o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda j: (j,)),
            pl.BlockSpec((bn,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), params.dtype),
        interpret=True,
    )(params, grads, lr.reshape(1))
