"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth against which python/tests/test_kernel.py checks
the kernels (exact schedule-independent math, no pallas involved).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_linear_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                     activation: str = "none") -> jax.Array:
    """act(x @ w + b), computed directly."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def grad_merge_ref(splits: jax.Array, average: bool = False) -> jax.Array:
    """Sum (or mean) of k gradient splits along axis 0."""
    acc = jnp.sum(splits.astype(jnp.float32), axis=0)
    if average:
        acc = acc / splits.shape[0]
    return acc.astype(splits.dtype)


def sgd_apply_ref(params: jax.Array, grads: jax.Array,
                  lr: jax.Array) -> jax.Array:
    return params - lr * grads
