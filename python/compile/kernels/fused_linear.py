"""L1 Pallas kernel: fused linear layer  y = act(x @ w + b).

This is the compute hot-spot of the transformer MLP (and the QKV/output
projections). The paper trains on CPU-only serverless functions with
PyTorch; we re-express the hot-spot for a TPU-style memory hierarchy:

  * the grid tiles M (rows) and N (cols) so each program instance owns one
    (BM, BN) output tile resident in VMEM;
  * the contraction dimension K is streamed in BK-sized blocks through a
    VMEM accumulator (float32), which is the MXU-friendly schedule
    (HBM -> VMEM double-buffering is expressed by the BlockSpec index_map);
  * bias add + activation are fused into the epilogue so the tile never
    round-trips to HBM between matmul and activation.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
executes byte-identically. Real-TPU tile-size/VMEM estimates live in
DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes. On a real TPU these map to the 128x128
# systolic array; on CPU (interpret mode) they only affect the loop
# structure, not correctness.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation: str,
                   n_k: int):
    """One (BM, BN) output tile; grid = (M/BM, N/BN, K/BK).

    The K axis is the innermost (fastest varying) grid dimension, so the
    float32 accumulator in VMEM scratch carries across K steps.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: bf16/f32 inputs, f32 accumulate.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...]
        if activation == "gelu":
            y = jax.nn.gelu(y)
        elif activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation != "none":
            raise ValueError(f"unknown activation {activation!r}")
        o_ref[...] = y.astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (keeps the grid exact)."""
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk")
)
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
) -> jax.Array:
    """act(x @ w + b) with a tiled Pallas kernel.

    x: (M, K)   w: (K, N)   b: (N,)   -> (M, N)
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = bm or _pick_block(m, DEFAULT_BM)
    bn = bn or _pick_block(n, DEFAULT_BN)
    bk = bk or _pick_block(k, DEFAULT_BK)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k},{n}) not divisible by blocks ({bm},{bk},{bn})"
    )
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_linear_kernel, activation=activation, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pl.MemoryRef(
                jax.core.ShapedArray((bm, bn), jnp.float32), pl.ANY
            )
        ],
        interpret=True,
    )(x, w, b)


# Some jax versions expose scratch differently; provide a robust wrapper
# that falls back to carrying the accumulator in the output ref.
def _linear_kernel_noscratch(x_ref, w_ref, b_ref, o_ref, *, activation: str,
                             n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if activation == "gelu":
            y = jax.nn.gelu(y)
        elif activation == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def fused_linear_noscratch(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "none",
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
) -> jax.Array:
    """Variant that accumulates in the output ref (no scratch memory).

    Functionally identical to `fused_linear`; used where the jax version's
    scratch-shape API is unavailable, and as the lowering target in model.py
    (one less VMEM buffer, same schedule).
    """
    m, k = x.shape
    _, n = w.shape
    bm = bm or _pick_block(m, DEFAULT_BM)
    bn = bn or _pick_block(n, DEFAULT_BN)
    bk = bk or _pick_block(k, DEFAULT_BK)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    n_k = k // bk
    kernel = functools.partial(
        _linear_kernel_noscratch, activation=activation, n_k=n_k
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


# ---------------------------------------------------------------------------
# Differentiable wrapper.
#
# JAX cannot auto-differentiate through a multi-K-step pallas_call (the
# program_id-indexed accumulator has no jvp rule), so the backward pass is
# supplied explicitly — and itself runs on the same tiled kernel:
#     z  = x@w + b
#     dz = gy * act'(z)
#     dx = dz @ w.T      dw = x.T @ dz      db = sum(dz, axis=0)
# z is rematerialized in the backward (no residual activations), matching
# the stage-level remat strategy of model.py.
# ---------------------------------------------------------------------------


def _matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain tiled matmul via the fused kernel (zero bias, no activation)."""
    zeros = jnp.zeros((b.shape[1],), a.dtype)
    return fused_linear_noscratch(a, b, zeros, activation="none")


def _act_grad(z: jax.Array, gy: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return gy
    if activation == "relu":
        return jnp.where(z > 0, gy, 0.0)
    if activation == "gelu":
        _, vjp = jax.vjp(jax.nn.gelu, z)
        (dz,) = vjp(gy)
        return dz
    raise ValueError(f"unknown activation {activation!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear_ad(x: jax.Array, w: jax.Array, b: jax.Array,
                    activation: str = "none") -> jax.Array:
    """Differentiable act(x @ w + b); fwd and bwd both on the Pallas kernel."""
    return fused_linear_noscratch(x, w, b, activation=activation)


def _fused_linear_fwd(x, w, b, activation):
    y = fused_linear_noscratch(x, w, b, activation=activation)
    return y, (x, w, b)


def _fused_linear_bwd(activation, res, gy):
    x, w, b = res
    z = fused_linear_noscratch(x, w, b, activation="none")  # remat
    dz = _act_grad(z, gy, activation)
    dx = _matmul(dz, w.T)
    dw = _matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear_ad.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set for one program instance.

    x-tile + w-tile + bias-tile + out/acc-tile (+ double-buffer factor 2 on
    the streamed inputs). Used by DESIGN.md's roofline estimate and by the
    block-shape sweep in python/tests/test_kernel.py::test_vmem_budget.
    """
    stream = 2 * (bm * bk + bk * bn) * dtype_bytes  # double-buffered
    resident = (bm * bn) * 4 + bn * dtype_bytes     # f32 accumulator + bias
    return stream + resident
