//! End-to-end training: the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example train_e2e
//!
//! Python (JAX + Pallas) has already AOT-compiled the staged transformer
//! to HLO text; this binary drives a **real pipelined training run**: one
//! thread per serverless "function", activations and gradients relayed
//! through the in-process object store (with per-worker bandwidth
//! throttling), intra-stage pipelined scatter-reduce, SGD through the AOT
//! executables, checkpoint/restart on function-lifetime expiry — and logs
//! the loss curve. Results are recorded in EXPERIMENTS.md.

use funcpipe::collective::SyncAlgorithm;
use funcpipe::trainer::{train, TrainConfig};

fn main() {
    funcpipe::util::logging::init();
    let steps: usize = std::env::var("FUNCPIPE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut cfg = TrainConfig::new("artifacts");
    cfg.dp = 2; // two replicas per stage -> pipelined scatter-reduce
    cfg.mu = 2; // μ micro-batches per worker per iteration
    cfg.steps = steps;
    cfg.lr = 0.2;
    cfg.sync_alg = SyncAlgorithm::PipelinedScatterReduce;
    // 40 MB/s per worker + 2 ms storage latency: a scaled-down Lambda
    cfg.throttle = Some((40.0e6, 0.002));
    // short lifetime so the Function Manager's checkpoint/restart path
    // runs several times during the demo (15 min on real Lambda)
    cfg.lifetime_s = 20.0;
    cfg.checkpoint_margin_s = 1.0;

    println!(
        "training the AOT transformer: {} stages x dp={} ({} workers), \
         {} steps, global batch {}",
        4,
        cfg.dp,
        4 * cfg.dp,
        cfg.steps,
        cfg.global_batch(4)
    );

    let report = train(&cfg).expect("training run");

    println!("\nloss curve (every 10th step):");
    for log in report.logs.iter().step_by(10) {
        println!("  step {:>4}  loss {:.4}", log.step, log.loss);
    }
    let last = report.logs.last().unwrap();
    println!("  step {:>4}  loss {:.4}", last.step, last.loss);
    println!(
        "\nfirst loss {:.4} (ln V = {:.4}), final loss {:.4}",
        report.first_loss(),
        (256f32).ln(),
        report.last_loss()
    );
    println!(
        "mean iteration {:.1} ms | wall {:.1} s | {} function restarts | \
         store ops: {} puts / {} gets",
        report.mean_iter_s() * 1e3,
        report.wall_s,
        report.restarts,
        report.store_put_gets.0,
        report.store_put_gets.1
    );
    assert!(
        report.last_loss() < report.first_loss(),
        "loss must decrease over the run"
    );
}
