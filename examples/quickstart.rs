//! Quickstart: the FuncPipe public API in ~40 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Loads a zoo model, co-optimizes partition + resources for AWS Lambda,
//! prints the Pareto sweep and the recommended configuration, then
//! cross-checks the prediction with the discrete-event simulator.

use funcpipe::collective::SyncAlgorithm;
use funcpipe::model::{merge_layers, zoo, MergeCriterion};
use funcpipe::pipeline::simulate_iteration;
use funcpipe::planner::{recommend, sweep, CoOptimizer, DEFAULT_WEIGHTS};
use funcpipe::platform::PlatformSpec;

fn main() {
    // 1. pick a platform and a model (Table 1 of the paper)
    let platform = PlatformSpec::aws_lambda();
    let model = merge_layers(
        &zoo::amoebanet_d18(&platform),
        8,
        MergeCriterion::Compute, // §4: merge to keep the MIQP tractable
    );

    // 2. co-optimize partition + data parallelism + memory tiers (§3.4)
    let global_batch = 64;
    let n_micro = global_batch / zoo::MICRO_BATCH;
    let optimizer = CoOptimizer::new(&model, &platform);
    let points = sweep(&DEFAULT_WEIGHTS, |w| {
        optimizer.solve(n_micro, w).map(|(plan, perf, _)| (plan, perf))
    });

    println!("Pareto sweep for AmoebaNet-D18, batch {global_batch}:");
    for p in &points {
        println!(
            "  α={:?}  {}  -> {:.2} s/iter, ${:.5}/iter",
            p.weights,
            p.plan.describe(&model, &platform),
            p.perf.t_iter,
            p.perf.c_iter
        );
    }

    // 3. the paper's δ≥0.8 recommendation rule (§5.1)
    let rec = recommend(&points).expect("a feasible plan exists");
    println!("\nrecommended: {}", rec.plan.describe(&model, &platform));

    // 4. validate the closed-form prediction with the DES (Table 3)
    let sim = simulate_iteration(
        &model,
        &platform,
        &rec.plan,
        SyncAlgorithm::PipelinedScatterReduce,
    );
    println!(
        "predicted {:.2} s/iter vs simulated {:.2} s/iter ({:.1}% error)",
        rec.perf.t_iter,
        sim.t_iter,
        (rec.perf.t_iter - sim.t_iter).abs() / sim.t_iter * 100.0
    );
}
