//! Plan explorer: sweep all four evaluation models × batch sizes across
//! both platforms, printing Pareto frontiers, recommendations and the
//! baseline comparison — the "what should I deploy?" workflow.
//!
//!     cargo run --release --example plan_explorer [-- <model>]

use funcpipe::baselines::{evaluate_baseline, BaselineKind};
use funcpipe::model::{merge_layers, zoo, MergeCriterion};
use funcpipe::planner::{pareto_front, recommend, sweep, CoOptimizer, DEFAULT_WEIGHTS};
use funcpipe::platform::pricing::{C5_9XLARGE, R7_2XLARGE};
use funcpipe::platform::PlatformSpec;
use funcpipe::util::humansize::{secs, usd};
use funcpipe::util::table::Table;

fn main() {
    let filter = std::env::args().nth(1);
    for platform in [PlatformSpec::aws_lambda(), PlatformSpec::alibaba_fc()] {
        let vm = if platform.name == "aws-lambda" {
            C5_9XLARGE
        } else {
            R7_2XLARGE
        };
        for name in zoo::MODEL_NAMES {
            if let Some(f) = &filter {
                if !name.contains(f.as_str()) {
                    continue;
                }
            }
            let zoo_m = zoo::by_name(name, &platform).unwrap();
            let model = merge_layers(&zoo_m, 8, MergeCriterion::Compute);
            for gb in [64usize, 256] {
                let mut t = Table::new(format!(
                    "{name} @ {} — batch {gb}",
                    platform.name
                ))
                .header(["configuration", "workers", "t_iter", "c_iter"]);

                let mut best_baseline: Option<f64> = None;
                for kind in BaselineKind::ALL {
                    if let Some(r) =
                        evaluate_baseline(kind, &zoo_m, &platform, gb, vm)
                    {
                        best_baseline = Some(
                            best_baseline
                                .map_or(r.t_iter, |b: f64| b.min(r.t_iter)),
                        );
                        t.row([
                            kind.name().to_string(),
                            r.n_workers.to_string(),
                            secs(r.t_iter),
                            usd(r.c_iter),
                        ]);
                    }
                }

                let opt = CoOptimizer::new(&model, &platform);
                let points = sweep(&DEFAULT_WEIGHTS, |w| {
                    opt.solve(gb / zoo::MICRO_BATCH, w)
                        .map(|(plan, perf, _)| (plan, perf))
                });
                let front = pareto_front(&points);
                let rec = recommend(&front);
                for p in &front {
                    let marker = rec
                        .as_ref()
                        .filter(|r| r.plan == p.plan)
                        .map(|_| " <- recommended")
                        .unwrap_or("");
                    t.row([
                        format!(
                            "FuncPipe {}{marker}",
                            p.plan.describe(&model, &platform)
                        ),
                        p.plan.n_workers().to_string(),
                        secs(p.perf.t_iter),
                        usd(p.perf.c_iter),
                    ]);
                }
                if let (Some(b), Some(r)) = (best_baseline, &rec) {
                    t.row([
                        format!(
                            "=> speedup vs best baseline: {:.2}x",
                            b / r.perf.t_iter
                        ),
                        String::new(),
                        String::new(),
                        String::new(),
                    ]);
                }
                t.print();
            }
        }
    }
}
