//! Scatter-reduce shoot-out: runs the *real threaded* implementations of
//! the LambdaML 3-phase scatter-reduce and FuncPipe's pipelined variant
//! over a bandwidth-throttled in-process object store, and compares wall
//! time with eqs. (1)/(2) — §3.3 made tangible.
//!
//!     cargo run --release --example scatter_reduce_demo

use std::sync::Arc;
use std::time::{Duration, Instant};

use funcpipe::collective::pipelined::{
    pipelined_scatter_reduce, pipelined_scatter_reduce_chunked,
};
use funcpipe::collective::scatter_reduce::scatter_reduce;
use funcpipe::collective::{sync_time, Chunking, SyncAlgorithm};
use funcpipe::platform::{MemStore, ObjectStore, ThrottledStore};
use funcpipe::util::table::Table;

#[derive(Clone, Copy)]
enum Variant {
    Plain,
    Pipelined,
    /// Pipelined with chunked flows: same transfers, bounded store
    /// occupancy — returns the peak relay-bucket bytes too.
    Chunked(Chunking),
}

fn run(n: usize, elems: usize, bw: f64, lat_ms: u64, v: Variant) -> (f64, u64) {
    let inner: Arc<dyn ObjectStore> = Arc::new(MemStore::new());
    let start = Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let store: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
                inner.clone(),
                bw,
                bw,
                Duration::from_millis(lat_ms),
            ));
            std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..elems).map(|i| (rank + i) as f32).collect();
                let timeout = Duration::from_secs(120);
                match v {
                    Variant::Plain => scatter_reduce(
                        &store, "demo", 0, rank, n, &mut grads, None, timeout,
                    )
                    .unwrap(),
                    Variant::Pipelined => pipelined_scatter_reduce(
                        &store, "demo", 0, rank, n, &mut grads, None, timeout,
                    )
                    .unwrap(),
                    Variant::Chunked(chunking) => {
                        pipelined_scatter_reduce_chunked(
                            &store, "demo", 0, rank, n, &mut grads, None,
                            timeout, chunking,
                        )
                        .unwrap()
                    }
                }
                grads[0] // touch the result
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (start.elapsed().as_secs_f64(), inner.high_water_bytes())
}

fn main() {
    // 8 MB of gradients per worker at 20 MB/s per direction: scaled-down
    // Lambda (70 MB/s x 280 MB in the paper's example, same ratio).
    let elems = 2_000_000;
    let bytes = (elems * 4) as f64;
    let bw = 20.0e6;
    let lat = 2u64;
    let chunking = Chunking::new(256 << 10, 4); // 256 KB flows, 4 in flight

    let mut t = Table::new(
        "real storage-based scatter-reduce (8 MB grads, 20 MB/s; chunked = 256 KB x 4)",
    )
    .header([
        "workers",
        "plain (wall)",
        "pipelined (wall)",
        "chunked (wall)",
        "cut",
        "peak bucket plain",
        "peak bucket chunked",
        "eq(1)",
        "eq(2)",
    ]);
    for n in [2usize, 4, 8] {
        let (plain, hwm_plain) = run(n, elems, bw, lat, Variant::Plain);
        let (piped, _) = run(n, elems, bw, lat, Variant::Pipelined);
        let (chunked, hwm_chunked) =
            run(n, elems, bw, lat, Variant::Chunked(chunking));
        t.row([
            n.to_string(),
            format!("{plain:.2} s"),
            format!("{piped:.2} s"),
            format!("{chunked:.2} s"),
            format!("{:.0}%", (1.0 - piped / plain) * 100.0),
            format!("{} KB", hwm_plain >> 10),
            format!("{} KB", hwm_chunked >> 10),
            format!(
                "{:.2} s",
                sync_time(SyncAlgorithm::ScatterReduce, bytes, n, bw, lat as f64 / 1e3)
            ),
            format!(
                "{:.2} s",
                sync_time(SyncAlgorithm::PipelinedScatterReduce, bytes, n, bw, lat as f64 / 1e3)
            ),
        ]);
    }
    t.print();
    println!(
        "duplex wins grow with n (bounded by the 33% transfer-time limit, §5.5); \
         chunking keeps the relay bucket at ~n * in_flight * chunk instead of the full gradient."
    );
}
