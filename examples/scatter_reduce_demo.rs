//! Scatter-reduce shoot-out: runs the *real threaded* implementations of
//! the LambdaML 3-phase scatter-reduce and FuncPipe's pipelined variant
//! over a bandwidth-throttled in-process object store, and compares wall
//! time with eqs. (1)/(2) — §3.3 made tangible.
//!
//!     cargo run --release --example scatter_reduce_demo

use std::sync::Arc;
use std::time::{Duration, Instant};

use funcpipe::collective::pipelined::pipelined_scatter_reduce;
use funcpipe::collective::scatter_reduce::scatter_reduce;
use funcpipe::collective::{sync_time, SyncAlgorithm};
use funcpipe::platform::{MemStore, ObjectStore, ThrottledStore};
use funcpipe::util::table::Table;

fn run(n: usize, elems: usize, bw: f64, lat_ms: u64, pipelined: bool) -> f64 {
    let inner = Arc::new(MemStore::new());
    let start = Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let store: Arc<dyn ObjectStore> = Arc::new(ThrottledStore::new(
                inner.clone(),
                bw,
                bw,
                Duration::from_millis(lat_ms),
            ));
            std::thread::spawn(move || {
                let mut grads: Vec<f32> =
                    (0..elems).map(|i| (rank + i) as f32).collect();
                if pipelined {
                    pipelined_scatter_reduce(
                        &store, "demo", 0, rank, n, &mut grads, None,
                        Duration::from_secs(120),
                    )
                    .unwrap();
                } else {
                    scatter_reduce(
                        &store, "demo", 0, rank, n, &mut grads, None,
                        Duration::from_secs(120),
                    )
                    .unwrap();
                }
                grads[0] // touch the result
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    start.elapsed().as_secs_f64()
}

fn main() {
    // 8 MB of gradients per worker at 20 MB/s per direction: scaled-down
    // Lambda (70 MB/s x 280 MB in the paper's example, same ratio).
    let elems = 2_000_000;
    let bytes = (elems * 4) as f64;
    let bw = 20.0e6;
    let lat = 2u64;

    let mut t = Table::new("real storage-based scatter-reduce (8 MB grads, 20 MB/s)")
        .header(["workers", "plain (wall)", "pipelined (wall)", "cut", "eq(1)", "eq(2)"]);
    for n in [2usize, 4, 8] {
        let plain = run(n, elems, bw, lat, false);
        let piped = run(n, elems, bw, lat, true);
        t.row([
            n.to_string(),
            format!("{plain:.2} s"),
            format!("{piped:.2} s"),
            format!("{:.0}%", (1.0 - piped / plain) * 100.0),
            format!(
                "{:.2} s",
                sync_time(SyncAlgorithm::ScatterReduce, bytes, n, bw, lat as f64 / 1e3)
            ),
            format!(
                "{:.2} s",
                sync_time(SyncAlgorithm::PipelinedScatterReduce, bytes, n, bw, lat as f64 / 1e3)
            ),
        ]);
    }
    t.print();
    println!("duplex wins grow with n, bounded by the 33% transfer-time limit (§5.5).");
}
