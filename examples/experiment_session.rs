//! The `Experiment` session API end to end: one unified config drives
//! plan → artifact → simulate → (gated) train, with typed reports.
//!
//!     cargo run --release --example experiment_session
//!
//! This is the library-caller view of exactly what the CLI does:
//!
//!     funcpipe plan --model amoebanet-d18 --batch 64 --out plan.json
//!     funcpipe simulate --plan plan.json
//!     funcpipe train --plan plan.json

use funcpipe::config::ExperimentConfig;
use funcpipe::experiment::{Experiment, Format, PlanArtifact, Report, TrainOverrides};

fn main() {
    // 1. one unified config for the whole session (§3.1's loop)
    let cfg = ExperimentConfig {
        model: "amoebanet-d18".into(),
        global_batch: 64,
        ..ExperimentConfig::default()
    };
    let exp = Experiment::new(cfg).expect("valid config");

    // 2. co-optimize: the Pareto front as a typed PlanReport
    let plans = exp.plan().expect("planning");
    print!("{}", plans.render(Format::Table));

    // 3. freeze the recommendation as a serializable artifact
    let rec = plans.recommended().expect("feasible plan");
    let path = std::env::temp_dir().join("funcpipe-demo-plan.json");
    rec.artifact.save(&path).expect("save artifact");
    println!("\nwrote {} — excerpt:", path.display());
    let text = rec.artifact.to_json_text();
    for line in text.lines().take(8) {
        println!("  {line}");
    }
    println!("  ...");

    // 4. anyone (any process) can reload it and act on it
    let loaded = PlanArtifact::load(&path).expect("load artifact");
    let exp2 = Experiment::from_artifact(&loaded).expect("compatible artifact");
    let sim = exp2.simulate(&loaded).expect("simulate");
    print!("\n{}", sim.render(Format::Table));
    println!("(same report as JSON: `--format json` on the CLI)");

    // 5. train from the plan — dp/μ/chunking come from the artifact, not
    //    hand-copied flags (needs `make artifacts` + --features xla-rt)
    match exp2.train(Some(&loaded), &TrainOverrides::default()) {
        Ok(run) => print!("\n{}", run.render(Format::Table)),
        Err(e) => println!("\ntrain skipped ({e:#})"),
    }
    std::fs::remove_file(&path).ok();
}
